//! `mikv` — the L3 coordinator binary.
//!
//! Subcommands:
//!   serve      start the TCP JSON-lines server
//!   generate   one-shot generation from a token prompt
//!   eval       run an evaluation task across cache modes
//!   info       print the artifact manifest summary
//!
//! Run `mikv help` for flags.

use mikv::coordinator::{CoordinatorConfig, Op, QosConfig, Scheduler};
use mikv::eval::{EvalTask, Harness};
use mikv::model::{CacheMode, Engine, Session};
use mikv::runtime::Manifest;
use mikv::server::{BackpressureConfig, ServeConfig};
use mikv::util::cli::Args;
use mikv::util::faults::FaultPlan;
use std::time::{Duration, Instant};

const USAGE: &str = "\
mikv — mixed-precision KV cache serving (MiKV reproduction)

USAGE: mikv <command> [--artifacts DIR] [--model NAME] [flags]

COMMANDS:
  serve      --port 7777 --workers 1 --max-active 8 --max-waiting 256
             --session-ttl 120 (secs) --session-mb 512
             --cold-dir DIR --cold-mb 256
             --qos [--qos-quantum 64 --qos-rate TOKENS_PER_SEC
             --qos-burst 512 --qos-inflight 4 --qos-backlog 256
             --qos-retry-ms 50]
             --writer-queue 1024 --write-timeout-ms 5000 --stall-ms 30000
             --fault-plan SPEC
             (Slow clients: each connection's writer queue is bounded by
              --writer-queue lines; when full, non-terminal token events
              are shed (counted in the events_dropped stat) while
              done/error lines are never shed, and a client making no
              write progress for --stall-ms is disconnected. --fault-plan
              arms deterministic fault injection for chaos drills, e.g.
              'engine_step_panic:every=50,limit=2;conn_stall:every=9';
              sites: engine_step_error, engine_step_panic,
              cold_put_before_write, cold_put_partial_write,
              cold_put_before_rename, cold_put_after_rename,
              cold_take_read, conn_stall, conn_disconnect, accept_error;
              keys: every/after/limit/ms, plus seed=N. Workers are
              supervised either way: a panicking worker is respawned,
              in-flight requests get structured internal errors, and
              cold-spilled sessions are recovered.)
             (Serving API v1: versioned streaming ops with multi-turn
              sessions, sharded across N engine workers with continuous
              batching per worker; see rust/src/server/proto.rs and
              EXPERIMENTS.md. --max-active/--max-waiting/--session-mb are
              per worker. --cold-dir enables the cold tier: parked
              sessions evicted by TTL or footprint pressure spill to disk
              snapshots under DIR, bounded by --cold-mb per worker, and
              are restored transparently on append. --qos turns on the
              multi-tenant admission layer: per-connection deficit
              round-robin fair queuing, an interactive lane ahead of the
              batch lane, optional per-tenant token-bucket rate limits
              [--qos-rate/--qos-burst in prompt+decode tokens], and
              graceful shedding once a worker's backlog exceeds
              --qos-backlog waiting turns — rejections carry a
              retry_after_ms hint of --qos-retry-ms. Without --qos,
              admission is the historical FCFS path, byte-identical on
              the wire.)
  generate   --prompt 1,2,3 --max-new 8 --mode mikv:0.25:int2
  eval       --task lineret --samples 25 --modes full,mikv:0.25:int2,h2o:0.25
  info       print manifest summary

MODES (for --mode / --modes):
  full | oracle:<k> | mikv:<ratio>:<lo>[:promote] | h2o:<ratio> | rtn:<prec>
  (mikv flags also: nobal, hi=<prec>, policy=<name>, recent=<n>, group=<n>)
";

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> anyhow::Result<()> {
    let artifacts = args.get_str("artifacts", "artifacts");
    let model = args.get_str("model", "cfg-s");

    match args.subcommand() {
        Some("info") => {
            let m = Manifest::load(&artifacts)?;
            for (name, e) in &m.models {
                println!(
                    "{name}: {:.2}M params, L={} Hq={} Hkv={} D={} S={}, trained {} steps",
                    e.dims.params as f64 / 1e6,
                    e.dims.n_layers,
                    e.dims.n_q_heads,
                    e.dims.n_kv_heads,
                    e.dims.d_head,
                    e.dims.max_seq,
                    e.train_steps,
                );
                for (g, ge) in &e.graphs {
                    println!("  graph {g}: {} ({} inputs)", ge.file, ge.inputs.len());
                }
            }
            Ok(())
        }
        Some("generate") => {
            let engine = Engine::load(&artifacts, &model)?;
            let prompt: Vec<i64> = args.get_list("prompt", &[] as &[i64])?;
            anyhow::ensure!(!prompt.is_empty(), "--prompt required (comma-separated ids)");
            let max_new = args.get("max-new", 8usize)?;
            let mode = CacheMode::parse(&args.get_str("mode", "full"), engine.dims())?;
            let mut sess = Session::new(0, engine.dims(), mode)?;
            let t0 = Instant::now();
            let out = engine.generate_greedy(&mut sess, &prompt, max_new, None)?;
            println!(
                "generated {:?} in {:.1}ms (cache {:.1}%)",
                out,
                t0.elapsed().as_secs_f64() * 1e3,
                sess.cache.cache_size_pct()
            );
            Ok(())
        }
        Some("eval") => {
            let engine = Engine::load(&artifacts, &model)?;
            let manifest = Manifest::load(&artifacts)?;
            mikv::eval::corpus::check_manifest_constants(&manifest.corpus)?;
            let task = match args.get_str("task", "lineret").as_str() {
                "lineret" => EvalTask::LineRet {
                    n_lines: args.get("lines", 20usize)?,
                    filler: args.get("filler", 0usize)?,
                },
                "multihop" => EvalTask::MultiHop {
                    n_lines: args.get("lines", 16usize)?,
                },
                "pattern" => EvalTask::Pattern {
                    motif: args.get("motif", 6usize)?,
                    repeats: args.get("repeats", 8usize)?,
                },
                "lm" => EvalTask::Lm {
                    context: args.get("context", 96usize)?,
                    answer: args.get("answer", 8usize)?,
                },
                "needle" => EvalTask::NeedleAtDepth {
                    depth_pct: args.get("depth", 0u8)?,
                    haystack: args.get("haystack", 96usize)?,
                },
                "drift" => EvalTask::MultiTurnDrift {
                    turns: args.get("turns", 8usize)?,
                    probe_every: args.get("probe-every", 2usize)?,
                },
                "keyedrecall" => EvalTask::KeyedRecall {
                    n_keys: args.get("keys", 16usize)?,
                },
                other => anyhow::bail!("unknown task '{other}'"),
            };
            let names: Vec<String> =
                args.get_list("modes", &["full".to_string(), "mikv:0.25:int2".to_string()])?;
            let modes: Vec<(String, CacheMode)> = names
                .iter()
                .map(|n| Ok((n.clone(), CacheMode::parse(n, engine.dims())?)))
                .collect::<anyhow::Result<_>>()?;
            let n = args.get("samples", 25usize)?;
            let harness = Harness::new(&engine);
            for o in harness.run(&task, &modes, n)? {
                println!(
                    "{:<18} {:<9} acc {:>6.1}%  worst-bucket {:>6.1}%  cache {:>6.1}%  (n={})",
                    o.mode_name,
                    o.task,
                    100.0 * o.accuracy,
                    100.0 * o.worst_bucket,
                    o.cache_pct,
                    o.n_samples
                );
            }
            Ok(())
        }
        Some("serve") => {
            let port: u16 = args.get("port", 7777u16)?;
            let workers = args.get_nonzero("workers", 1)?;
            let cold_dir = args.get_str("cold-dir", "");
            // Deterministic fault injection (off unless --fault-plan is
            // given). One shared plan is threaded through the engine
            // workers, the cold tier and the TCP front-end so a chaos
            // drill's occurrence counts reconcile across fault domains.
            let faults = FaultPlan::parse(&args.get_str("fault-plan", ""))?;
            let cfg = CoordinatorConfig {
                max_active: args.get("max-active", 8usize)?,
                prefill_chunk: args.get("prefill-chunk", 4usize)?,
                max_waiting: args.get("max-waiting", 256usize)?,
                session_ttl: Duration::from_secs(args.get("session-ttl", 120u64)?),
                max_session_bytes: args.get("session-mb", 512usize)? << 20,
                cold_dir: (!cold_dir.is_empty()).then(|| cold_dir.clone().into()),
                max_cold_bytes: args.get("cold-mb", 256u64)? << 20,
                faults: faults.clone(),
                ..Default::default()
            };
            let bp_defaults = BackpressureConfig::default();
            let serve_cfg = ServeConfig {
                backpressure: BackpressureConfig {
                    queue_depth: args.get_nonzero(
                        "writer-queue",
                        bp_defaults.queue_depth,
                    )?,
                    write_timeout: Duration::from_millis(args.get(
                        "write-timeout-ms",
                        bp_defaults.write_timeout.as_millis() as u64,
                    )?),
                    stall_deadline: Duration::from_millis(args.get(
                        "stall-ms",
                        bp_defaults.stall_deadline.as_millis() as u64,
                    )?),
                },
                faults,
            };
            // --qos opts into the multi-tenant admission layer; absent,
            // the QoS machinery is not constructed and admission is the
            // regression-locked FCFS path.
            let qos = args.flag("qos").then(|| -> anyhow::Result<QosConfig> {
                let defaults = QosConfig::default();
                let rate = args.get("qos-rate", 0.0f64)?;
                Ok(QosConfig {
                    quantum: args.get_nonzero("qos-quantum", defaults.quantum)?,
                    rate: (rate > 0.0).then_some(rate),
                    burst: args.get("qos-burst", defaults.burst)?,
                    inflight_per_worker: args
                        .get_nonzero("qos-inflight", defaults.inflight_per_worker)?,
                    max_backlog: args.get_nonzero("qos-backlog", defaults.max_backlog)?,
                    retry_after_ms: args.get("qos-retry-ms", defaults.retry_after_ms)?,
                })
            });
            let qos = qos.transpose()?;
            // Each worker loads its own engine on its own thread (PJRT
            // handles are not `Send`); `--workers 1` is the original
            // single-loop deployment.
            let scheduler = Scheduler::start_with_qos(workers, cfg, qos, move |w| {
                let engine = Engine::load(&artifacts, &model)?;
                mikv::log_info!("worker {w}: engine ready");
                Ok(engine)
            })?;
            let (tx, rx) = std::sync::mpsc::channel::<Op>();
            let listener = std::net::TcpListener::bind(("127.0.0.1", port))?;
            let stop = mikv::server::StopHandle::for_listener(&listener)?;
            std::thread::spawn(move || {
                let _ = mikv::server::serve_until_with(listener, tx, stop, serve_cfg);
            });
            scheduler.run(rx);
            Ok(())
        }
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}
