//! PJRT client wrapper: load HLO-text artifacts, compile, execute.
//!
//! Follows the `/opt/xla-example/load_hlo` recipe: HLO *text* is the
//! interchange format (`HloModuleProto::from_text_file` reassigns the
//! 64-bit instruction ids jax ≥ 0.5 emits, which xla_extension 0.5.1's
//! proto path rejects), `return_tuple=True` lowering means every execution
//! returns one tuple literal that is unpacked into per-output literals.
//!
//! Weights and other long-lived inputs are uploaded once as device-resident
//! [`xla::PjRtBuffer`]s and passed by reference via `execute_b` — the
//! per-step host→device traffic is only the cache/token inputs.
//!
//! PJRT handles are not `Send`; the serving design keeps one [`Runtime`]
//! on a dedicated engine thread (see `coordinator`), with request/response
//! channels crossing threads instead of buffers.

use super::artifacts::{Dtype, GraphEntry, TensorSpec};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

/// Host-side input value for one graph parameter.
pub enum HostInput<'a> {
    F32(&'a [f32]),
    I64(&'a [i64]),
}

impl<'a> HostInput<'a> {
    pub fn len(&self) -> usize {
        match self {
            HostInput::F32(s) => s.len(),
            HostInput::I64(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn dtype(&self) -> Dtype {
        match self {
            HostInput::F32(_) => Dtype::F32,
            HostInput::I64(_) => Dtype::I64,
        }
    }
}

/// The PJRT runtime (CPU client).
pub struct Runtime {
    client: PjRtClient,
}

impl Runtime {
    pub fn new() -> crate::Result<Runtime> {
        let client = PjRtClient::cpu().map_err(anyhow::Error::msg)?;
        crate::log_debug!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime { client })
    }

    /// Load an HLO text file and compile it against this client.
    pub fn load_executable(
        &self,
        path: &std::path::Path,
        entry: GraphEntry,
    ) -> crate::Result<Executable> {
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path).map_err(anyhow::Error::msg)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(anyhow::Error::msg)?;
        crate::log_info!(
            "compiled {} ({} inputs) in {:.2}s",
            path.file_name().map(|s| s.to_string_lossy()).unwrap_or_default(),
            entry.inputs.len(),
            t0.elapsed().as_secs_f64()
        );
        Ok(Executable { exe, entry })
    }

    /// Upload one host tensor to the device, validating against its spec.
    pub fn upload(&self, spec: &TensorSpec, value: &HostInput<'_>) -> crate::Result<PjRtBuffer> {
        anyhow::ensure!(
            value.dtype() == spec.dtype,
            "input '{}': dtype mismatch",
            spec.name
        );
        anyhow::ensure!(
            value.len() == spec.numel(),
            "input '{}': {} elements, spec {:?} wants {}",
            spec.name,
            value.len(),
            spec.shape,
            spec.numel()
        );
        let buf = match value {
            HostInput::F32(data) => {
                self.client
                    .buffer_from_host_buffer::<f32>(data, &spec.shape, None)
            }
            HostInput::I64(data) => {
                self.client
                    .buffer_from_host_buffer::<i64>(data, &spec.shape, None)
            }
        };
        buf.map_err(anyhow::Error::msg)
    }

    /// Upload a raw f32 slice with explicit dims (no spec validation).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> crate::Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .map_err(anyhow::Error::msg)
    }
}

/// A compiled graph plus its manifest I/O contract.
pub struct Executable {
    exe: PjRtLoadedExecutable,
    pub entry: GraphEntry,
}

impl Executable {
    /// Execute with device-resident buffers; returns one host literal per
    /// declared output (the lowered tuple is unpacked).
    pub fn execute(&self, args: &[&PjRtBuffer]) -> crate::Result<Vec<Literal>> {
        anyhow::ensure!(
            args.len() == self.entry.inputs.len(),
            "graph {}: got {} args, expects {}",
            self.entry.file,
            args.len(),
            self.entry.inputs.len()
        );
        let outs = self.exe.execute_b(args).map_err(anyhow::Error::msg)?;
        let tuple = outs[0][0].to_literal_sync().map_err(anyhow::Error::msg)?;
        let parts = tuple.to_tuple().map_err(anyhow::Error::msg)?;
        anyhow::ensure!(
            parts.len() == self.entry.outputs.len(),
            "graph {}: produced {} outputs, manifest says {}",
            self.entry.file,
            parts.len(),
            self.entry.outputs.len()
        );
        Ok(parts)
    }

    /// Convenience: fetch output literal values as f32 by output name.
    pub fn output_f32(&self, outputs: &[Literal], name: &str) -> crate::Result<Vec<f32>> {
        let idx = self
            .entry
            .outputs
            .iter()
            .position(|o| o == name)
            .ok_or_else(|| anyhow::anyhow!("graph {} has no output '{name}'", self.entry.file))?;
        outputs[idx].to_vec::<f32>().map_err(anyhow::Error::msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, dtype: Dtype, shape: &[usize]) -> TensorSpec {
        TensorSpec {
            name: name.into(),
            dtype,
            shape: shape.to_vec(),
        }
    }

    // The full load→compile→execute path is covered by rust/tests/
    // integration tests against real artifacts; here we test the
    // validation logic that doesn't need artifacts.

    #[test]
    fn upload_validates_shape_and_dtype() {
        let rt = match Runtime::new() {
            Ok(rt) => rt,
            Err(_) => return, // no PJRT in this environment — skip
        };
        let s = spec("x", Dtype::F32, &[2, 2]);
        assert!(rt.upload(&s, &HostInput::F32(&[1.0, 2.0, 3.0, 4.0])).is_ok());
        assert!(rt.upload(&s, &HostInput::F32(&[1.0])).is_err());
        assert!(rt.upload(&s, &HostInput::I64(&[1, 2, 3, 4])).is_err());
    }

    #[test]
    fn host_input_len() {
        assert_eq!(HostInput::F32(&[0.0; 5]).len(), 5);
        assert_eq!(HostInput::I64(&[1, 2]).len(), 2);
    }
}
