//! PJRT runtime: loads and executes the AOT artifacts.
//!
//! The compile path (`python/compile/aot.py`, run once by `make artifacts`)
//! leaves behind HLO **text** files plus `manifest.json` and `.mikv` weight
//! checkpoints; everything here is pure rust on top of the `xla` crate's
//! PJRT CPU client — Python is never on the request path.
//!
//! * [`artifacts`] — manifest parsing: model configs, graph I/O contracts.
//! * [`weights`] — `.mikv` tensor container reader.
//! * [`client`] — [`client::Runtime`]: PJRT client + graph loading + typed
//!   execution (host tensors in, host tensors out, device-resident weight
//!   buffers reused across steps).

pub mod artifacts;
pub mod client;
pub mod weights;

pub use artifacts::{GraphEntry, Manifest, ModelDims, TensorSpec};
pub use client::{Executable, Runtime};
pub use weights::Weights;
