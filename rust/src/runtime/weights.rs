//! `.mikv` tensor container reader (mirrors `python/compile/tensorio.py`).
//!
//! Format: `b"MIKV\x01\n"` magic, u64-LE header length, UTF-8 JSON header
//! (`{"meta": ..., "tensors": [{name, dtype, shape, offset, nbytes}]}`),
//! then a raw little-endian data blob with 64-byte-aligned tensors.

use crate::tensor::{TensorF32, TensorI64};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

const MAGIC: &[u8] = b"MIKV\x01\n";

/// A tensor loaded from a `.mikv` file.
#[derive(Debug, Clone)]
pub enum AnyTensor {
    F32(TensorF32),
    I64(TensorI64),
}

impl AnyTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            AnyTensor::F32(t) => t.shape(),
            AnyTensor::I64(t) => t.shape(),
        }
    }

    pub fn as_f32(&self) -> Option<&TensorF32> {
        match self {
            AnyTensor::F32(t) => Some(t),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<&TensorI64> {
        match self {
            AnyTensor::I64(t) => Some(t),
            _ => None,
        }
    }
}

/// A parsed `.mikv` file: named tensors (order preserved) + JSON metadata.
#[derive(Debug)]
pub struct Weights {
    pub meta: Json,
    order: Vec<String>,
    tensors: BTreeMap<String, AnyTensor>,
}

impl Weights {
    pub fn load(path: impl AsRef<Path>) -> crate::Result<Weights> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Self::parse(&bytes).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    pub fn parse(bytes: &[u8]) -> crate::Result<Weights> {
        if bytes.len() < MAGIC.len() + 8 || &bytes[..MAGIC.len()] != MAGIC {
            anyhow::bail!("bad magic (not a .mikv file)");
        }
        let mut off = MAGIC.len();
        let hdrlen = u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap()) as usize;
        off += 8;
        let header = std::str::from_utf8(&bytes[off..off + hdrlen])?;
        let root = Json::parse(header)?;
        let data = &bytes[off + hdrlen..];

        let mut order = Vec::new();
        let mut tensors = BTreeMap::new();
        for e in root.field_arr("tensors")? {
            let name = e.field_str("name")?.to_string();
            let shape: Vec<usize> = e
                .field_arr("shape")?
                .iter()
                .map(|d| d.as_i64().unwrap_or(0) as usize)
                .collect();
            let t_off = e.field_i64("offset")? as usize;
            let nbytes = e.field_i64("nbytes")? as usize;
            if t_off + nbytes > data.len() {
                anyhow::bail!("tensor '{name}' extends beyond data section");
            }
            let raw = &data[t_off..t_off + nbytes];
            let t = match e.field_str("dtype")? {
                "f32" => {
                    let vals: Vec<f32> = raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    AnyTensor::F32(TensorF32::from_vec(&shape, vals))
                }
                "i64" => {
                    let vals: Vec<i64> = raw
                        .chunks_exact(8)
                        .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    AnyTensor::I64(TensorI64::from_vec(&shape, vals))
                }
                other => anyhow::bail!("unknown dtype '{other}'"),
            };
            order.push(name.clone());
            tensors.insert(name, t);
        }
        Ok(Weights {
            meta: root.field("meta").cloned().unwrap_or(Json::Null),
            order,
            tensors,
        })
    }

    pub fn names(&self) -> &[String] {
        &self.order
    }

    pub fn get(&self, name: &str) -> Option<&AnyTensor> {
        self.tensors.get(name)
    }

    pub fn get_f32(&self, name: &str) -> crate::Result<&TensorF32> {
        self.get(name)
            .and_then(AnyTensor::as_f32)
            .ok_or_else(|| anyhow::anyhow!("tensor '{name}' missing or not f32"))
    }

    pub fn get_i64(&self, name: &str) -> crate::Result<&TensorI64> {
        self.get(name)
            .and_then(AnyTensor::as_i64)
            .ok_or_else(|| anyhow::anyhow!("tensor '{name}' missing or not i64"))
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a .mikv byte blob in-process (mirrors tensorio.write_tensors).
    fn build(tensors: &[(&str, &str, Vec<usize>, Vec<u8>)]) -> Vec<u8> {
        let mut entries = String::from("[");
        let mut data = Vec::new();
        for (i, (name, dtype, shape, raw)) in tensors.iter().enumerate() {
            let pad = (64 - data.len() % 64) % 64;
            data.extend(std::iter::repeat(0u8).take(pad));
            let off = data.len();
            data.extend_from_slice(raw);
            if i > 0 {
                entries.push(',');
            }
            let shape_s: Vec<String> = shape.iter().map(|d| d.to_string()).collect();
            entries.push_str(&format!(
                r#"{{"name":"{name}","dtype":"{dtype}","shape":[{}],"offset":{off},"nbytes":{}}}"#,
                shape_s.join(","),
                raw.len()
            ));
        }
        entries.push(']');
        let header = format!(r#"{{"meta":{{"k":1}},"tensors":{entries}}}"#);
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(header.len() as u64).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.extend_from_slice(&data);
        out
    }

    #[test]
    fn parses_f32_and_i64() {
        let f: Vec<u8> = [1.5f32, -2.0, 0.25]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let i: Vec<u8> = [7i64, -9].iter().flat_map(|v| v.to_le_bytes()).collect();
        let blob = build(&[
            ("a", "f32", vec![3], f),
            ("b", "i64", vec![2], i),
        ]);
        let w = Weights::parse(&blob).unwrap();
        assert_eq!(w.names(), &["a", "b"]);
        assert_eq!(w.get_f32("a").unwrap().data(), &[1.5, -2.0, 0.25]);
        assert_eq!(w.get_i64("b").unwrap().data(), &[7, -9]);
        assert_eq!(w.meta.field_i64("k").unwrap(), 1);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(Weights::parse(b"WRONG!xxxxxxxxxx").is_err());
    }

    #[test]
    fn rejects_out_of_bounds_tensor() {
        let blob = build(&[("a", "f32", vec![100], vec![0u8; 8])]);
        // claims 100 elements = 400 bytes but only 8 present... the builder
        // writes nbytes=8, so shape mismatch surfaces at Tensor::from_vec
        let res = std::panic::catch_unwind(|| Weights::parse(&blob));
        assert!(res.is_err() || res.unwrap().is_err());
    }

    #[test]
    fn typed_getters_check_dtype() {
        let f: Vec<u8> = 1.0f32.to_le_bytes().to_vec();
        let blob = build(&[("a", "f32", vec![1], f)]);
        let w = Weights::parse(&blob).unwrap();
        assert!(w.get_i64("a").is_err());
        assert!(w.get_f32("missing").is_err());
    }
}
