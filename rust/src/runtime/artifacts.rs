//! Artifact manifest parsing.
//!
//! `artifacts/manifest.json` (written by `python/compile/aot.py`) indexes
//! every HLO graph with its exact input/output contract, the weight
//! checkpoints, the golden parity fixtures, and the corpus constants that
//! `rust/src/eval/corpus.rs` cross-checks against its own definitions.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Element type of a graph input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I64,
}

/// One graph input tensor contract.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered graph.
#[derive(Debug, Clone)]
pub struct GraphEntry {
    pub file: String,
    pub batch: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<String>,
}

/// Model dimensions (mirrors `python/compile/model.py::ModelConfig`).
#[derive(Debug, Clone)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub quant_group: usize,
    pub params: usize,
}

impl ModelDims {
    pub fn planes(&self) -> usize {
        self.n_layers * self.n_kv_heads
    }

    /// Scale/zero groups per token per head.
    pub fn n_groups(&self) -> usize {
        self.d_head / self.quant_group
    }
}

/// One model's artifact set.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub dims: ModelDims,
    pub weights_file: String,
    pub train_steps: i64,
    pub param_order: Vec<String>,
    /// Keyed `"{kind}-b{batch}"`, e.g. `"decode_mikv-b1"`.
    pub graphs: BTreeMap<String, GraphEntry>,
    /// Bulk quantization graphs keyed by bit width.
    pub quant_graphs: BTreeMap<u32, String>,
    /// Golden fixture files keyed by batch size.
    pub goldens: BTreeMap<usize, String>,
}

impl ModelEntry {
    /// Batch sizes a graph kind was compiled for, ascending.
    pub fn batches(&self, kind: &str) -> Vec<usize> {
        let prefix = format!("{kind}-b");
        let mut v: Vec<usize> = self
            .graphs
            .keys()
            .filter_map(|k| k.strip_prefix(&prefix).and_then(|b| b.parse().ok()))
            .collect();
        v.sort_unstable();
        v
    }

    pub fn graph(&self, kind: &str, batch: usize) -> Option<&GraphEntry> {
        self.graphs.get(&format!("{kind}-b{batch}"))
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelEntry>,
    /// Corpus constants for cross-checking `eval::corpus`.
    pub corpus: BTreeMap<String, i64>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> crate::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("{}: {e} (run `make artifacts` first)", path.display()))?;
        let root = Json::parse(&text)?;

        let mut corpus = BTreeMap::new();
        for (k, v) in root.field("corpus")?.as_obj().unwrap().iter() {
            corpus.insert(k.to_string(), v.as_i64().unwrap_or(0));
        }

        let mut models = BTreeMap::new();
        for (name, m) in root.field("models")?.as_obj().unwrap().iter() {
            models.insert(name.to_string(), parse_model(name, m)?);
        }
        Ok(Manifest { dir, models, corpus })
    }

    pub fn model(&self, name: &str) -> crate::Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model '{name}' not in manifest (have: {:?})", self.models.keys()))
    }

    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

fn parse_model(name: &str, m: &Json) -> crate::Result<ModelEntry> {
    let c = m.field("config")?;
    let dims = ModelDims {
        vocab: c.field_i64("vocab")? as usize,
        d_model: c.field_i64("d_model")? as usize,
        n_layers: c.field_i64("n_layers")? as usize,
        n_q_heads: c.field_i64("n_q_heads")? as usize,
        n_kv_heads: c.field_i64("n_kv_heads")? as usize,
        d_head: c.field_i64("d_head")? as usize,
        d_ff: c.field_i64("d_ff")? as usize,
        max_seq: c.field_i64("max_seq")? as usize,
        quant_group: c.field_i64("quant_group")? as usize,
        params: c.field_i64("params")? as usize,
    };

    let param_order = m
        .field_arr("param_order")?
        .iter()
        .map(|v| v.as_str().unwrap_or_default().to_string())
        .collect();

    let mut graphs = BTreeMap::new();
    for (gname, g) in m.field("graphs")?.as_obj().unwrap().iter() {
        let inputs = g
            .field_arr("inputs")?
            .iter()
            .map(|i| {
                Ok(TensorSpec {
                    name: i.field_str("name")?.to_string(),
                    dtype: match i.field_str("dtype")? {
                        "f32" => Dtype::F32,
                        "i64" => Dtype::I64,
                        other => anyhow::bail!("unknown dtype {other}"),
                    },
                    shape: i
                        .field_arr("shape")?
                        .iter()
                        .map(|d| d.as_i64().unwrap_or(0) as usize)
                        .collect(),
                })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        graphs.insert(
            gname.to_string(),
            GraphEntry {
                file: g.field_str("file")?.to_string(),
                batch: g.field_i64("batch")? as usize,
                inputs,
                outputs: g
                    .field_arr("outputs")?
                    .iter()
                    .map(|o| o.as_str().unwrap_or_default().to_string())
                    .collect(),
            },
        );
    }

    let mut quant_graphs = BTreeMap::new();
    if let Ok(qg) = m.field("quant_graphs") {
        for (bits, g) in qg.as_obj().unwrap().iter() {
            if let (Ok(b), Ok(f)) = (bits.parse::<u32>(), g.field_str("file")) {
                quant_graphs.insert(b, f.to_string());
            }
        }
    }

    let mut goldens = BTreeMap::new();
    if let Ok(gl) = m.field("goldens") {
        for (b, f) in gl.as_obj().unwrap().iter() {
            if let (Ok(b), Some(f)) = (b.parse::<usize>(), f.as_str()) {
                goldens.insert(b, f.to_string());
            }
        }
    }

    Ok(ModelEntry {
        name: name.to_string(),
        dims,
        weights_file: m.field_str("weights")?.to_string(),
        train_steps: m.field_i64("train_steps").unwrap_or(0),
        param_order,
        graphs,
        quant_graphs,
        goldens,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "corpus": {"BOS": 1, "VOCAB": 512},
      "models": {
        "cfg-x": {
          "config": {"vocab": 64, "d_model": 32, "n_layers": 2, "n_q_heads": 4,
                     "n_kv_heads": 2, "d_head": 8, "d_ff": 64, "max_seq": 16,
                     "rope_theta": 10000.0, "quant_group": 4, "params": 1000},
          "weights": "weights-cfg-x.mikv",
          "train_steps": 5,
          "param_order": ["embed", "lnf"],
          "graphs": {
            "decode_mikv-b1": {
              "file": "cfg-x-decode_mikv-b1.hlo.txt", "batch": 1,
              "inputs": [{"name": "w.embed", "dtype": "f32", "shape": [64, 32]},
                         {"name": "token", "dtype": "i64", "shape": [1]}],
              "outputs": ["logits"]
            },
            "decode_mikv-b4": {
              "file": "f.hlo.txt", "batch": 4,
              "inputs": [], "outputs": ["logits"]
            }
          },
          "quant_graphs": {"2": {"file": "q2.hlo.txt", "rows": 16, "dim": 8, "group": 4}},
          "goldens": {"1": "golden-cfg-x-b1.mikv"}
        }
      }
    }"#;

    fn write_sample(dir: &std::path::Path) {
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
    }

    #[test]
    fn parses_sample_manifest() {
        let dir = std::env::temp_dir().join("mikv-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.corpus["VOCAB"], 512);
        let me = m.model("cfg-x").unwrap();
        assert_eq!(me.dims.planes(), 4);
        assert_eq!(me.dims.n_groups(), 2);
        assert_eq!(me.batches("decode_mikv"), vec![1, 4]);
        let g = me.graph("decode_mikv", 1).unwrap();
        assert_eq!(g.inputs.len(), 2);
        assert_eq!(g.inputs[1].dtype, Dtype::I64);
        assert_eq!(me.quant_graphs[&2], "q2.hlo.txt");
        assert_eq!(me.goldens[&1], "golden-cfg-x-b1.mikv");
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn missing_manifest_mentions_make() {
        let err = Manifest::load("/nonexistent-dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn tensor_spec_numel() {
        let t = TensorSpec {
            name: "x".into(),
            dtype: Dtype::F32,
            shape: vec![2, 3, 4],
        };
        assert_eq!(t.numel(), 24);
    }
}
