//! Per-request generation session state.

use crate::kvcache::accounting::Occupancy;
use crate::kvcache::dirty::{DirtyTake, DirtyTracker};
use crate::kvcache::{
    BufferPool, CacheConfig, CacheManager, PromotionStats, StepOutputs,
};
use crate::policies::make_policy;
use crate::quant::Precision;
use crate::runtime::ModelDims;

/// How a session's cache behaves — selects both the decode graph and the
/// cache data structure.
#[derive(Debug, Clone)]
pub enum CacheMode {
    /// Mixed-precision cache (also covers the H2O-eviction and RTN
    /// baselines via the [`CacheConfig`] presets). `policy` is one of
    /// "h2o" | "local" | "random".
    Mikv { cfg: CacheConfig, policy: String },
    /// Exact full-precision cache (the paper's 100% baseline).
    Full,
    /// Full cache with post-softmax oracle top-k (paper Fig. 3b):
    /// keep the `k` highest attention weights per head, renormalize.
    Oracle { k: usize },
}

impl CacheMode {
    /// Graph kind this mode decodes with.
    pub fn graph_kind(&self) -> &'static str {
        match self {
            CacheMode::Mikv { .. } => "decode_mikv",
            CacheMode::Full | CacheMode::Oracle { .. } => "decode_full",
        }
    }

    /// Convenience preset: paper-default MiKV at an importance ratio.
    pub fn mikv(dims: &ModelDims, ratio: f64, lo: Precision) -> CacheMode {
        CacheMode::Mikv {
            cfg: CacheConfig::mikv(
                dims.n_layers,
                dims.n_kv_heads,
                dims.d_head,
                dims.max_seq,
                ratio,
                lo,
            ),
            policy: "h2o".into(),
        }
    }

    /// H2O eviction baseline preset.
    pub fn h2o(dims: &ModelDims, ratio: f64) -> CacheMode {
        CacheMode::Mikv {
            cfg: CacheConfig::h2o(
                dims.n_layers,
                dims.n_kv_heads,
                dims.d_head,
                dims.max_seq,
                ratio,
            ),
            policy: "h2o".into(),
        }
    }

    /// Uniform RTN quantization baseline preset.
    pub fn rtn(dims: &ModelDims, precision: Precision) -> CacheMode {
        CacheMode::Mikv {
            cfg: CacheConfig::rtn(
                dims.n_layers,
                dims.n_kv_heads,
                dims.d_head,
                dims.max_seq,
                precision,
            ),
            policy: "h2o".into(),
        }
    }

    /// Parse a mode string:
    /// `full` | `oracle:<k>` | `h2o:<ratio>` | `rtn:<prec>` |
    /// `mikv:<ratio>:<lo>[:<flag>...]` with flags `nobal` (disable outlier
    /// awareness), `hi=<prec>` (quantized importance cache, paper §3.3),
    /// `policy=<name>`, `recent=<n>`, `group=<n>`, `promote` (enable the
    /// lo→hi promotion pass with default knobs), `evict` (drop demoted
    /// tokens instead of retaining them lo — the eviction baseline with
    /// every other knob still addressable), `merge` (WeightedKV-style
    /// merge-instead-of-drop with default knobs; meaningful with `evict`).
    pub fn parse(s: &str, dims: &ModelDims) -> crate::Result<CacheMode> {
        let parts: Vec<&str> = s.split(':').collect();
        let prec = |p: &str| {
            Precision::parse(p).ok_or_else(|| anyhow::anyhow!("bad precision '{p}' in '{s}'"))
        };
        Ok(match *parts.first().unwrap_or(&"") {
            "full" => CacheMode::Full,
            "oracle" => CacheMode::Oracle {
                k: parts
                    .get(1)
                    .and_then(|p| p.parse().ok())
                    .unwrap_or(dims.max_seq + 1),
            },
            "h2o" => CacheMode::h2o(
                dims,
                parts
                    .get(1)
                    .and_then(|p| p.parse().ok())
                    .unwrap_or(0.2),
            ),
            "rtn" => CacheMode::rtn(dims, prec(parts.get(1).copied().unwrap_or("int8"))?),
            "mikv" => {
                let ratio: f64 = parts.get(1).and_then(|p| p.parse().ok()).unwrap_or(0.2);
                let lo = prec(parts.get(2).copied().unwrap_or("int2"))?;
                let mut mode = Self::mikv(dims, ratio, lo);
                if let CacheMode::Mikv { cfg, policy } = &mut mode {
                    for flag in parts.get(3..).unwrap_or(&[]) {
                        if *flag == "nobal" {
                            cfg.outlier_aware = false;
                        } else if *flag == "promote" {
                            cfg.promotion =
                                Some(crate::kvcache::PromotionConfig::default());
                        } else if *flag == "evict" {
                            cfg.retention = crate::kvcache::RetentionMode::Evict;
                        } else if *flag == "merge" {
                            cfg.merge = Some(crate::kvcache::MergeConfig::default());
                        } else if let Some(p) = flag.strip_prefix("hi=") {
                            let hp = prec(p)?;
                            cfg.hi = if hp.is_quantized() {
                                crate::kvcache::TierConfig::quantized(
                                    hp,
                                    (dims.d_head / 2).max(1),
                                )
                            } else {
                                crate::kvcache::TierConfig::fp16()
                            };
                        } else if let Some(p) = flag.strip_prefix("policy=") {
                            *policy = p.to_string();
                        } else if let Some(n) = flag.strip_prefix("recent=") {
                            cfg.recent_window = n.parse()?;
                        } else if let Some(n) = flag.strip_prefix("group=") {
                            cfg.lo = crate::kvcache::TierConfig::quantized(lo, n.parse()?);
                        } else {
                            anyhow::bail!("unknown mikv flag '{flag}' in '{s}'");
                        }
                    }
                }
                mode
            }
            other => anyhow::bail!("unknown mode '{other}'"),
        })
    }
}

/// Dense full-precision cache used by the Full/Oracle modes.
#[derive(Debug, Clone)]
pub struct FullCache {
    planes: usize,
    d: usize,
    s_max: usize,
    /// `[planes, s_max, d]`
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// `[planes, s_max]` — 1.0 for live slots.
    pub mask: Vec<f32>,
    pub seq_len: usize,
    /// Rows touched since the engine last synchronized this cache (the
    /// same delta-assembly handshake the MiKV manager uses — appends dirty
    /// one row, prefill dirties everything).
    dirty: DirtyTracker,
}

impl FullCache {
    pub fn new(dims: &ModelDims) -> FullCache {
        let planes = dims.planes();
        let (d, s) = (dims.d_head, dims.max_seq);
        FullCache {
            planes,
            d,
            s_max: s,
            k: vec![0.0; planes * s * d],
            v: vec![0.0; planes * s * d],
            mask: vec![0.0; planes * s],
            seq_len: 0,
            dirty: DirtyTracker::new(),
        }
    }

    /// Ingest prefill K/V (`[planes, t, d]` contiguous) for a prompt of
    /// length `t`.
    // lint: panic-free-serving-ok(fn): every range is derived from t/planes/d, asserted at entry
    pub fn ingest_prefill(&mut self, t: usize, k: &[f32], v: &[f32]) {
        assert!(t <= self.s_max);
        assert_eq!(k.len(), self.planes * t * self.d);
        for p in 0..self.planes {
            let src = p * t * self.d..(p * t + t) * self.d;
            let dst = p * self.s_max * self.d..(p * self.s_max + t) * self.d;
            self.k[dst.clone()].copy_from_slice(&k[src.clone()]);
            self.v[dst].copy_from_slice(&v[src]);
            self.mask[p * self.s_max..p * self.s_max + t].fill(1.0);
        }
        self.seq_len = t;
        self.dirty.mark_all();
    }

    /// Drain the rows touched since the last take (delta-assembly
    /// handshake; see [`crate::kvcache::dirty`]).
    pub fn take_dirty_into(&mut self, out: &mut Vec<usize>) -> DirtyTake {
        self.dirty.take_into(out)
    }

    /// Invalidate every row for the next assembly — the snapshot-restore
    /// contract: a cache rebuilt from a cold snapshot has no arena lane to
    /// delta against, so the first post-restore assembly must be a full
    /// rescatter (fresh tracker epoch ⇒ the engine's version handshake
    /// misses and it rebuilds the lane from scratch).
    pub fn mark_all_dirty(&mut self) {
        self.dirty.mark_all();
    }

    /// Plane count (layers × kv-heads) — snapshot header validation.
    pub fn planes(&self) -> usize {
        self.planes
    }

    /// Per-head channel count.
    pub fn head_dim(&self) -> usize {
        self.d
    }

    /// Maximum sequence length the dense blocks are sized for.
    pub fn max_seq(&self) -> usize {
        self.s_max
    }

    /// Host bytes pinned by the dense cache blocks (plus the dirty-row
    /// tracker's bookkeeping, mirroring `CacheManager::host_footprint`).
    pub fn host_bytes(&self) -> usize {
        (self.k.len() + self.v.len() + self.mask.len()) * std::mem::size_of::<f32>()
            + self.dirty.host_bytes()
    }

    /// Tier occupancy view: every live slot of the dense cache counts as hi.
    pub fn occupancy(&self) -> Occupancy {
        Occupancy {
            hi_slots: (self.planes * self.seq_len) as u64,
            ..Occupancy::default()
        }
    }

    /// Append one token's K/V (`[planes, d]`).
    // lint: panic-free-serving-ok(fn): slot t < s_max is asserted; serving bounds via try_ingest_step
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32]) {
        let t = self.seq_len;
        assert!(t < self.s_max, "cache full");
        for p in 0..self.planes {
            let dst = (p * self.s_max + t) * self.d;
            self.k[dst..dst + self.d].copy_from_slice(&k_new[p * self.d..(p + 1) * self.d]);
            self.v[dst..dst + self.d].copy_from_slice(&v_new[p * self.d..(p + 1) * self.d]);
            self.mask[p * self.s_max + t] = 1.0;
        }
        self.seq_len = t + 1;
        self.dirty.mark(t);
    }
}

/// The cache variant held by a session.
pub enum SessionCache {
    Mikv(CacheManager),
    Full(FullCache),
}

impl SessionCache {
    pub fn seq_len(&self) -> usize {
        match self {
            SessionCache::Mikv(m) => m.seq_len(),
            SessionCache::Full(f) => f.seq_len,
        }
    }

    /// Logical cache size in % of the uncompressed FP16 cache.
    pub fn cache_size_pct(&self) -> f64 {
        match self {
            SessionCache::Mikv(m) => m.cache_size_pct(),
            SessionCache::Full(_) => 100.0,
        }
    }

    /// Host bytes this cache currently pins (shadow blocks + tier storage
    /// for MiKV; the dense blocks for the Full baseline).
    pub fn host_bytes(&self) -> usize {
        match self {
            SessionCache::Mikv(m) => m.host_footprint().total(),
            SessionCache::Full(f) => f.host_bytes(),
        }
    }

    /// Tier occupancy (hi/lo/evicted slot counts summed over planes) — the
    /// per-turn serving report that shows multi-turn sessions carrying
    /// their tiers across turns.
    pub fn occupancy(&self) -> Occupancy {
        match self {
            SessionCache::Mikv(m) => m.occupancy(),
            SessionCache::Full(f) => f.occupancy(),
        }
    }

    /// Cumulative lo→hi promotion counters (zero for the Full baseline and
    /// for MiKV sessions without the opt-in promotion config).
    pub fn promotion_stats(&self) -> PromotionStats {
        match self {
            SessionCache::Mikv(m) => m.promotion_stats(),
            SessionCache::Full(_) => PromotionStats::default(),
        }
    }
}

/// One generation request's state.
pub struct Session {
    pub id: u64,
    pub mode: CacheMode,
    pub cache: SessionCache,
    /// Full token history: prompt then generated tokens.
    pub tokens: Vec<i64>,
    pub prompt_len: usize,
    /// Next token to feed (already appended to `tokens`).
    pub last_token: i64,
    pub done: bool,
}

impl Session {
    /// Create an empty session with a private buffer pool; the engine's
    /// prefill fills the cache. The serving coordinator uses
    /// [`Session::with_pool`] so cache blocks recycle across requests.
    pub fn new(id: u64, dims: &ModelDims, mode: CacheMode) -> crate::Result<Session> {
        Self::with_pool(id, dims, mode, &BufferPool::new())
    }

    /// Create an empty session whose MiKV cache blocks are checked out of
    /// (and returned to) the given pool.
    pub fn with_pool(
        id: u64,
        dims: &ModelDims,
        mode: CacheMode,
        pool: &BufferPool,
    ) -> crate::Result<Session> {
        let cache = match &mode {
            CacheMode::Mikv { cfg, policy } => {
                let p = make_policy(policy, cfg.layers * cfg.kv_heads, cfg.max_seq, id)
                    .ok_or_else(|| anyhow::anyhow!("unknown policy '{policy}'"))?;
                SessionCache::Mikv(CacheManager::with_pool(cfg.clone(), p, pool.clone()))
            }
            CacheMode::Full | CacheMode::Oracle { .. } => {
                SessionCache::Full(FullCache::new(dims))
            }
        };
        Ok(Session {
            id,
            mode,
            cache,
            tokens: Vec::new(),
            prompt_len: 0,
            last_token: 0,
            done: false,
        })
    }

    pub fn generated(&self) -> &[i64] {
        // lint: panic-free-serving-ok: prompt_len never exceeds tokens.len() by construction
        &self.tokens[self.prompt_len..]
    }

    /// Ingest one decode step's outputs into the cache.
    // lint: panic-free-serving-ok(fn): infallible wrapper for eval/bench drivers; serving calls try_ingest_step
    pub fn ingest_step(
        &mut self,
        k_new: &[f32],
        v_new: &[f32],
        attn_prev: &[f32],
        attn_self: &[f32],
    ) {
        self.try_ingest_step(k_new, v_new, attn_prev, attn_self)
            .expect("cache overflow (callers must bound seq_len)");
    }

    /// Fallible variant of [`Self::ingest_step`] used on the serving path
    /// (including multi-turn prompt re-ingest, where appended prompt tokens
    /// are fed through the decode graph into the same hi/lo tiers): a full
    /// cache surfaces as an error the coordinator maps onto the
    /// `cache_full` wire code instead of a panic.
    pub fn try_ingest_step(
        &mut self,
        k_new: &[f32],
        v_new: &[f32],
        attn_prev: &[f32],
        attn_self: &[f32],
    ) -> crate::Result<()> {
        match &mut self.cache {
            SessionCache::Mikv(m) => m.try_append_token(StepOutputs {
                k_new,
                v_new,
                attn_prev,
                attn_self,
            }),
            SessionCache::Full(f) => {
                anyhow::ensure!(
                    f.seq_len < f.s_max,
                    "cache full: {} of {} slots",
                    f.seq_len,
                    f.s_max
                );
                f.append(k_new, v_new);
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_q_heads: 4,
            n_kv_heads: 2,
            d_head: 8,
            d_ff: 64,
            max_seq: 16,
            quant_group: 4,
            params: 0,
        }
    }

    #[test]
    fn full_cache_prefill_and_append() {
        let d = dims();
        let mut fc = FullCache::new(&d);
        let planes = d.planes();
        let t = 5;
        let k: Vec<f32> = (0..planes * t * 8).map(|i| i as f32).collect();
        fc.ingest_prefill(t, &k, &k);
        assert_eq!(fc.seq_len, 5);
        // plane 1, slot 2, channel 3 == k[1*5*8 + 2*8 + 3]
        assert_eq!(fc.k[(1 * 16 + 2) * 8 + 3], (1 * 5 * 8 + 2 * 8 + 3) as f32);
        assert_eq!(fc.mask[16 + 4], 1.0);
        assert_eq!(fc.mask[16 + 5], 0.0);

        let k_new = vec![7.0; planes * 8];
        fc.append(&k_new, &k_new);
        assert_eq!(fc.seq_len, 6);
        assert_eq!(fc.k[(0 * 16 + 5) * 8], 7.0);
        assert_eq!(fc.mask[5], 1.0);
    }

    #[test]
    fn session_modes_pick_graphs() {
        let d = dims();
        assert_eq!(CacheMode::Full.graph_kind(), "decode_full");
        assert_eq!(CacheMode::Oracle { k: 4 }.graph_kind(), "decode_full");
        assert_eq!(
            CacheMode::mikv(&d, 0.25, Precision::Int2).graph_kind(),
            "decode_mikv"
        );
    }

    #[test]
    fn fresh_mikv_session_has_tiny_footprint() {
        let d = dims();
        let s = Session::new(1, &d, CacheMode::mikv(&d, 0.5, Precision::Int4)).unwrap();
        // no prefill yet → no shadow blocks checked out of the pool
        assert!(s.cache.host_bytes() < 4096, "got {}", s.cache.host_bytes());
        let full = Session::new(2, &d, CacheMode::Full).unwrap();
        assert!(full.cache.host_bytes() > 0);
    }

    #[test]
    fn mode_parse_promote_flag() {
        let d = dims();
        match CacheMode::parse("mikv:0.25:int4:promote", &d).unwrap() {
            CacheMode::Mikv { cfg, .. } => {
                assert_eq!(
                    cfg.promotion,
                    Some(crate::kvcache::PromotionConfig::default())
                );
            }
            other => panic!("not mikv: {other:?}"),
        }
        // without the flag promotion stays off
        match CacheMode::parse("mikv:0.25:int4", &d).unwrap() {
            CacheMode::Mikv { cfg, .. } => assert_eq!(cfg.promotion, None),
            other => panic!("not mikv: {other:?}"),
        }
        // promotion stats are zero for the Full baseline
        let s = Session::new(1, &d, CacheMode::Full).unwrap();
        assert_eq!(s.cache.promotion_stats(), PromotionStats::default());
    }

    #[test]
    fn mode_parse_evict_and_merge_flags() {
        let d = dims();
        match CacheMode::parse("mikv:0.25:int4:evict:merge:policy=lagkv", &d).unwrap() {
            CacheMode::Mikv { cfg, policy } => {
                assert_eq!(cfg.retention, crate::kvcache::RetentionMode::Evict);
                assert_eq!(cfg.merge, Some(crate::kvcache::MergeConfig::default()));
                assert_eq!(policy, "lagkv");
            }
            other => panic!("not mikv: {other:?}"),
        }
        // without the flags, retention stays Retain and merge stays off —
        // the default-off regression lock at the wire grammar level
        match CacheMode::parse("mikv:0.25:int4", &d).unwrap() {
            CacheMode::Mikv { cfg, .. } => {
                assert_eq!(cfg.retention, crate::kvcache::RetentionMode::Retain);
                assert_eq!(cfg.merge, None);
            }
            other => panic!("not mikv: {other:?}"),
        }
    }

    #[test]
    fn session_construction() {
        let d = dims();
        let s = Session::new(1, &d, CacheMode::mikv(&d, 0.5, Precision::Int4)).unwrap();
        assert_eq!(s.cache.seq_len(), 0);
        let s2 = Session::new(2, &d, CacheMode::Full).unwrap();
        assert_eq!(s2.cache.cache_size_pct(), 100.0);
        let bad = Session::new(
            3,
            &d,
            CacheMode::Mikv {
                cfg: crate::kvcache::CacheConfig::full(2, 2, 8, 16),
                policy: "nope".into(),
            },
        );
        assert!(bad.is_err());
    }
}
