//! Greedy sampling — the paper's evaluation protocol ("deterministic greedy
//! decoding for controlled assessment", Appendix D).

/// Argmax over one logits row.
pub fn greedy(logits: &[f32]) -> i64 {
    debug_assert!(!logits.is_empty());
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best = i;
            best_v = v;
        }
    }
    best as i64
}

/// Argmax restricted to a token sub-range `[lo, hi)` — used by evaluation
/// drivers that know the answer alphabet (e.g. line-retrieval values).
pub fn greedy_in_range(logits: &[f32], lo: usize, hi: usize) -> i64 {
    debug_assert!(lo < hi && hi <= logits.len());
    lo as i64 + greedy(&logits[lo..hi])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        assert_eq!(greedy(&[0.1, 2.0, -1.0, 1.9]), 1);
        assert_eq!(greedy(&[-5.0]), 0);
    }

    #[test]
    fn greedy_first_wins_ties() {
        assert_eq!(greedy(&[1.0, 1.0, 1.0]), 0);
    }

    #[test]
    fn range_restricted() {
        let logits = [9.0, 0.1, 0.5, 0.2, 9.0];
        assert_eq!(greedy_in_range(&logits, 1, 4), 2);
    }
}
