//! The inference engine: batched prefill/decode over the AOT graphs.
//!
//! One [`Engine`] owns a model's compiled executables and its weights as
//! device-resident PJRT buffers (uploaded once at load). Each step:
//!
//! 1. assemble the batch host tensors into the engine's reusable
//!    [`StepArena`]s (`model::assembly`): steady-state lanes copy only the
//!    rows their session's cache touched since the previous step, with a
//!    full live-prefix rescatter as the fallback — and no per-step heap
//!    allocation either way;
//! 2. upload + execute the right graph (`decode_mikv` or `decode_full`);
//! 3. scatter the outputs back: append the new token's K/V to each cache,
//!    feed the attention row to the importance policy, return logits.
//!
//! Sessions with different cache *configurations* batch together freely on
//! the MiKV graph (the config lives in the masks/codes, not the graph);
//! Full and Oracle sessions share the `decode_full` graph when their
//! `oracle_k` agrees.
//!
//! Arena lanes are keyed by a session's **rank in its decode group**: the
//! chunk covering group offsets `i..i + b` assembles into arena lanes
//! `i..i + b` (`assemble_*_at`), so a `decode_step` that splits into
//! several chunks gives each chunk a disjoint lane range and a stable
//! group keeps the dirty-row delta path on EVERY lane — not just the
//! first chunk's. The remaining (correctness-preserving) fallback: when
//! several decode groups share a graph kind in one scheduler round (e.g.
//! concurrent distinct `oracle_k` groups on `decode_full`), each group's
//! ranks start at 0, so the overlapping lanes full-rescatter.

use super::assembly::{assemble_full_at, assemble_mikv_at, StepArena};
use super::sampler;
use super::session::{CacheMode, Session, SessionCache};
use crate::runtime::artifacts::{Manifest, ModelDims, ModelEntry};
use crate::runtime::client::{Executable, HostInput, Runtime};
use crate::runtime::weights::Weights;
use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;
use xla::PjRtBuffer;

/// Raw prefill outputs for one session (used by the experiment harness to
/// build many cache variants from one prefill — see `eval::runner`).
pub struct PrefillOutput {
    pub seq_len: usize,
    /// `[planes, seq_len, d]`
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// `[planes, seq_len]`
    pub attn_acc: Vec<f32>,
    /// `[planes, d]`
    pub qmax: Vec<f32>,
    pub kmax: Vec<f32>,
    /// Logits at the last live prompt position, `[vocab]`.
    pub last_logits: Vec<f32>,
}

/// The per-model inference engine.
pub struct Engine {
    rt: Runtime,
    pub entry: ModelEntry,
    weight_bufs: Vec<PjRtBuffer>,
    prefill: BTreeMap<usize, Executable>,
    decode_mikv: BTreeMap<usize, Executable>,
    decode_full: BTreeMap<usize, Executable>,
    // Reusable decode-step host tensors (one arena per graph kind). The
    // engine lives on one thread (PJRT handles are not `Send`); RefCell
    // gives the `&self` step methods interior mutability without locks.
    arena_mikv: RefCell<StepArena>,
    arena_full: RefCell<StepArena>,
    /// Host-side assembly nanoseconds spent in the current/most recent
    /// `decode_step` call (reset at entry, accumulated across chunks).
    assembly_ns: Cell<u64>,
}

impl Engine {
    /// Load a model's artifacts: compile all its graphs, upload weights.
    pub fn load(artifacts_dir: impl AsRef<Path>, model: &str) -> crate::Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        Self::load_from_manifest(&manifest, model)
    }

    pub fn load_from_manifest(manifest: &Manifest, model: &str) -> crate::Result<Engine> {
        let entry = manifest.model(model)?.clone();
        let rt = Runtime::new()?;

        let mut prefill = BTreeMap::new();
        let mut decode_mikv = BTreeMap::new();
        let mut decode_full = BTreeMap::new();
        for (key, g) in &entry.graphs {
            let exe = rt.load_executable(&manifest.path(&g.file), g.clone())?;
            let map = if key.starts_with("prefill") {
                &mut prefill
            } else if key.starts_with("decode_mikv") {
                &mut decode_mikv
            } else {
                &mut decode_full
            };
            map.insert(g.batch, exe);
        }
        anyhow::ensure!(!prefill.is_empty(), "model {model} has no prefill graph");

        // Upload weights once (device-resident across all steps).
        let w = Weights::load(manifest.path(&entry.weights_file))?;
        let mut weight_bufs = Vec::with_capacity(entry.param_order.len());
        for name in &entry.param_order {
            let t = w.get_f32(name)?;
            weight_bufs.push(rt.upload_f32(t.data(), t.shape())?);
        }
        crate::log_info!(
            "engine ready: model={model} params={} graphs={} weights uploaded",
            entry.dims.params,
            entry.graphs.len()
        );
        let arena_mikv = RefCell::new(StepArena::for_mikv(&entry.dims));
        let arena_full = RefCell::new(StepArena::for_full(&entry.dims));
        Ok(Engine {
            rt,
            entry,
            weight_bufs,
            prefill,
            decode_mikv,
            decode_full,
            arena_mikv,
            arena_full,
            assembly_ns: Cell::new(0),
        })
    }

    pub fn dims(&self) -> &ModelDims {
        &self.entry.dims
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Host-side input-assembly time (µs) of the most recent
    /// [`Self::decode_step`] call.
    pub fn last_assembly_us(&self) -> f64 {
        self.assembly_ns.get() as f64 / 1e3
    }

    /// Compiled batch sizes for a graph kind.
    pub fn batches(&self, kind: &str) -> Vec<usize> {
        match kind {
            "prefill" => self.prefill.keys().copied().collect(),
            "decode_mikv" => self.decode_mikv.keys().copied().collect(),
            "decode_full" => self.decode_full.keys().copied().collect(),
            _ => vec![],
        }
    }

    // ------------------------------------------------------------------
    // Prefill
    // ------------------------------------------------------------------

    /// Run prefill for a set of prompts, returning raw outputs per prompt.
    /// Chunks across the compiled batch sizes automatically.
    pub fn prefill_raw(&self, prompts: &[Vec<i64>]) -> crate::Result<Vec<PrefillOutput>> {
        let mut out = Vec::with_capacity(prompts.len());
        let avail: Vec<usize> = self.prefill.keys().copied().collect();
        let mut i = 0;
        while i < prompts.len() {
            let remaining = prompts.len() - i;
            let b = pick_batch(remaining, &avail);
            let chunk = &prompts[i..(i + b.min(remaining))];
            out.extend(self.prefill_chunk(chunk, b)?);
            i += chunk.len();
        }
        Ok(out)
    }

    fn prefill_chunk(&self, prompts: &[Vec<i64>], b: usize) -> crate::Result<Vec<PrefillOutput>> {
        let exe = &self.prefill[&b];
        let d = &self.entry.dims;
        let (s, dh, v_sz) = (d.max_seq, d.d_head, d.vocab);
        let planes = d.planes();

        let mut tokens = vec![0i64; b * s];
        let mut len_mask = vec![0.0f32; b * s];
        for (lane, p) in prompts.iter().enumerate() {
            anyhow::ensure!(p.len() <= s, "prompt len {} > max_seq {s}", p.len());
            anyhow::ensure!(!p.is_empty(), "empty prompt");
            tokens[lane * s..lane * s + p.len()].copy_from_slice(p);
            len_mask[lane * s..lane * s + p.len()].fill(1.0);
        }

        let n_w = self.weight_bufs.len();
        let bufs = vec![
            self.rt.upload(&exe.entry.inputs[n_w], &HostInput::I64(&tokens))?,
            self.rt.upload(&exe.entry.inputs[n_w + 1], &HostInput::F32(&len_mask))?,
        ];
        let mut args: Vec<&PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend(bufs.iter());
        let outs = exe.execute(&args)?;

        let logits = exe.output_f32(&outs, "logits")?; // [B, S, V]
        let k = exe.output_f32(&outs, "k")?; // [B, L, H, S, D]
        let v = exe.output_f32(&outs, "v")?;
        let acc = exe.output_f32(&outs, "attn_acc")?; // [B, L, H, S]
        let qmax = exe.output_f32(&outs, "qmax")?; // [B, L, H, D]
        let kmax = exe.output_f32(&outs, "kmax")?;

        let mut results = Vec::with_capacity(prompts.len());
        for (lane, p) in prompts.iter().enumerate() {
            let t = p.len();
            // k/v: gather [planes, t, dh] from the padded [planes, s, dh]
            let mut kk = vec![0.0f32; planes * t * dh];
            let mut vv = vec![0.0f32; planes * t * dh];
            let mut aa = vec![0.0f32; planes * t];
            let base = lane * planes * s;
            for pl in 0..planes {
                let src = (base + pl * s) * dh..(base + pl * s + t) * dh;
                kk[pl * t * dh..(pl + 1) * t * dh].copy_from_slice(&k[src.clone()]);
                vv[pl * t * dh..(pl + 1) * t * dh].copy_from_slice(&v[src]);
                aa[pl * t..(pl + 1) * t]
                    .copy_from_slice(&acc[base + pl * s..base + pl * s + t]);
            }
            let mbase = lane * planes * dh;
            results.push(PrefillOutput {
                seq_len: t,
                k: kk,
                v: vv,
                attn_acc: aa,
                qmax: qmax[mbase..mbase + planes * dh].to_vec(),
                kmax: kmax[mbase..mbase + planes * dh].to_vec(),
                last_logits: logits[(lane * s + t - 1) * v_sz..(lane * s + t) * v_sz].to_vec(),
            });
        }
        Ok(results)
    }

    /// Prefill + ingest into sessions. Sets `tokens`/`prompt_len` and the
    /// first greedy `last_token`. Returns last-position logits per session.
    pub fn prefill(
        &self,
        sessions: &mut [&mut Session],
        prompts: &[Vec<i64>],
    ) -> crate::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(sessions.len() == prompts.len());
        let raw = self.prefill_raw(prompts)?;
        let mut logits_rows = Vec::with_capacity(raw.len());
        for ((sess, prompt), out) in sessions.iter_mut().zip(prompts).zip(raw) {
            self.ingest_prefill(sess, prompt, &out);
            logits_rows.push(out.last_logits);
        }
        Ok(logits_rows)
    }

    /// Ingest precomputed prefill outputs into a fresh session (the
    /// experiment harness fans one prefill out to many cache variants).
    pub fn ingest_prefill(&self, sess: &mut Session, prompt: &[i64], out: &PrefillOutput) {
        sess.tokens = prompt.to_vec();
        sess.prompt_len = prompt.len();
        match &mut sess.cache {
            SessionCache::Mikv(m) => {
                m.ingest_prefill(out.seq_len, &out.k, &out.v, &out.attn_acc, &out.qmax, &out.kmax)
            }
            SessionCache::Full(f) => f.ingest_prefill(out.seq_len, &out.k, &out.v),
        }
        sess.last_token = sampler::greedy(&out.last_logits);
        sess.tokens.push(sess.last_token);
    }

    // ------------------------------------------------------------------
    // Decode
    // ------------------------------------------------------------------

    /// One decode step for a homogeneous group of sessions (same graph
    /// kind; Oracle sessions must share `k`). Feeds each session's
    /// `last_token`, ingests the new KV + attention, returns logits rows.
    pub fn decode_step(&self, sessions: &mut [&mut Session]) -> crate::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(!sessions.is_empty());
        let kind = sessions[0].mode.graph_kind();
        anyhow::ensure!(
            sessions.iter().all(|s| s.mode.graph_kind() == kind),
            "decode batch mixes graph kinds"
        );
        let map = if kind == "decode_mikv" {
            &self.decode_mikv
        } else {
            &self.decode_full
        };
        let avail: Vec<usize> = map.keys().copied().collect();
        anyhow::ensure!(!avail.is_empty(), "no {kind} graph compiled");
        self.assembly_ns.set(0);

        let mut logits_rows = Vec::with_capacity(sessions.len());
        let mut i = 0;
        while i < sessions.len() {
            let remaining = sessions.len() - i;
            let b = pick_batch(remaining, &avail);
            let n = b.min(remaining);
            let chunk = &mut sessions[i..i + n];
            // `i` keys the chunk's arena lanes: each chunk of the group
            // owns lanes `i..i + b`, so multi-chunk steps keep per-lane
            // deltas (see the module docs).
            let rows = if kind == "decode_mikv" {
                self.decode_chunk_mikv(chunk, &map[&b], i)?
            } else {
                self.decode_chunk_full(chunk, &map[&b], i)?
            };
            logits_rows.extend(rows);
            i += n;
        }
        Ok(logits_rows)
    }

    fn decode_chunk_mikv(
        &self,
        sessions: &mut [&mut Session],
        exe: &Executable,
        base: usize,
    ) -> crate::Result<Vec<Vec<f32>>> {
        let d = &self.entry.dims;
        let b = exe.entry.batch;
        let n = sessions.len();

        // Delta-aware, allocation-free assembly into the reusable arena:
        // lanes whose session kept its lane since the previous step copy
        // only the dirty rows; padding lanes stay zero via the watermark
        // re-zeroing (masks 0 ⇒ a pad lane attends only to its own token;
        // outputs are discarded).
        let t0 = Instant::now();
        let mut arena = self.arena_mikv.borrow_mut();
        assemble_mikv_at(&mut arena, d, base, b, sessions)?;
        self.assembly_ns
            .set(self.assembly_ns.get() + t0.elapsed().as_nanos() as u64);

        let n_w = self.weight_bufs.len();
        let specs = &exe.entry.inputs;
        // Upload this chunk's b-lane range (the arena's lane capacity is
        // the grow-only max over chunk base + batch, so it may hold other
        // chunks' lanes on either side).
        let host: Vec<HostInput<'_>> = vec![
            HostInput::I64(arena.token_range(base, b)),
            HostInput::I64(arena.pos_range(base, b)),
            HostInput::F32(arena.block_range(0, base, b)), // k_hi
            HostInput::F32(arena.block_range(1, base, b)), // v_hi
            HostInput::F32(arena.block_range(2, base, b)), // hi_mask
            HostInput::F32(arena.block_range(3, base, b)), // k_lo_codes
            HostInput::F32(arena.block_range(4, base, b)), // k_lo_scale
            HostInput::F32(arena.block_range(5, base, b)), // k_lo_zero
            HostInput::F32(arena.block_range(6, base, b)), // v_lo_codes
            HostInput::F32(arena.block_range(7, base, b)), // v_lo_scale
            HostInput::F32(arena.block_range(8, base, b)), // v_lo_zero
            HostInput::F32(arena.block_range(9, base, b)), // lo_mask
            HostInput::F32(arena.extra_range(base, b)),    // inv_balancer
        ];
        let bufs = host
            .iter()
            .enumerate()
            .map(|(j, h)| self.rt.upload(&specs[n_w + j], h))
            .collect::<crate::Result<Vec<_>>>()?;
        drop(host);
        drop(arena);
        let mut args: Vec<&PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend(bufs.iter());
        let outs = exe.execute(&args)?;
        self.scatter_decode_outputs(sessions, exe, &outs, n)
    }

    fn decode_chunk_full(
        &self,
        sessions: &mut [&mut Session],
        exe: &Executable,
        base: usize,
    ) -> crate::Result<Vec<Vec<f32>>> {
        let d = &self.entry.dims;
        let b = exe.entry.batch;
        let s = d.max_seq;

        // Oracle homogeneity is a mode property — resolve it before the
        // assembly mutates arena state.
        let mut oracle_k: i64 = (s + 1) as i64;
        for sess in sessions.iter() {
            if let CacheMode::Oracle { k } = sess.mode {
                oracle_k = k as i64;
            }
        }
        for sess in sessions.iter() {
            match sess.mode {
                CacheMode::Oracle { k } => {
                    anyhow::ensure!(k as i64 == oracle_k, "mixed oracle_k in batch")
                }
                CacheMode::Full => {
                    anyhow::ensure!(oracle_k == (s + 1) as i64, "mixed Full/Oracle batch")
                }
                _ => {}
            }
        }

        let t0 = Instant::now();
        let mut arena = self.arena_full.borrow_mut();
        assemble_full_at(&mut arena, d, base, b, sessions)?;
        self.assembly_ns
            .set(self.assembly_ns.get() + t0.elapsed().as_nanos() as u64);

        let n_w = self.weight_bufs.len();
        let specs = &exe.entry.inputs;
        let ok = [oracle_k];
        let host: Vec<HostInput<'_>> = vec![
            HostInput::I64(arena.token_range(base, b)),
            HostInput::I64(arena.pos_range(base, b)),
            HostInput::F32(arena.block_range(0, base, b)), // k
            HostInput::F32(arena.block_range(1, base, b)), // v
            HostInput::F32(arena.block_range(2, base, b)), // mask
            HostInput::I64(&ok),
        ];
        let bufs = host
            .iter()
            .enumerate()
            .map(|(j, h)| self.rt.upload(&specs[n_w + j], h))
            .collect::<crate::Result<Vec<_>>>()?;
        drop(host);
        drop(arena);
        let mut args: Vec<&PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend(bufs.iter());
        let outs = exe.execute(&args)?;
        self.scatter_decode_outputs(sessions, exe, &outs, sessions.len())
    }

    /// Common decode output handling: per live lane, append KV + attention
    /// to the cache and collect the logits row.
    fn scatter_decode_outputs(
        &self,
        sessions: &mut [&mut Session],
        exe: &Executable,
        outs: &[xla::Literal],
        n_live: usize,
    ) -> crate::Result<Vec<Vec<f32>>> {
        let d = &self.entry.dims;
        let planes = d.planes();
        let (s, dh, v_sz) = (d.max_seq, d.d_head, d.vocab);

        let logits = exe.output_f32(outs, "logits")?; // [B, V]
        let k_new = exe.output_f32(outs, "k_new")?; // [B, planes, D]
        let v_new = exe.output_f32(outs, "v_new")?;
        let attn_prev = exe.output_f32(outs, "attn_prev")?; // [B, planes, S]
        let attn_self = exe.output_f32(outs, "attn_self")?; // [B, planes]

        let mut rows = Vec::with_capacity(n_live);
        for (lane, sess) in sessions.iter_mut().enumerate().take(n_live) {
            // Fallible ingest: a capacity overflow surfaces as a decode
            // error (the coordinator retires the group with a structured
            // `internal`/`cache_full` response) rather than aborting the
            // engine thread.
            sess.try_ingest_step(
                &k_new[lane * planes * dh..(lane + 1) * planes * dh],
                &v_new[lane * planes * dh..(lane + 1) * planes * dh],
                &attn_prev[lane * planes * s..(lane + 1) * planes * s],
                &attn_self[lane * planes..(lane + 1) * planes],
            )?;
            rows.push(logits[lane * v_sz..(lane + 1) * v_sz].to_vec());
        }
        Ok(rows)
    }

    /// Greedy autoregressive generation for one session.
    pub fn generate_greedy(
        &self,
        sess: &mut Session,
        prompt: &[i64],
        max_new: usize,
        stop: Option<i64>,
    ) -> crate::Result<Vec<i64>> {
        let mut group = [sess];
        self.prefill(&mut group, std::slice::from_ref(&prompt.to_vec()))?;
        for _ in 1..max_new {
            if let Some(stop_tok) = stop {
                if group[0].last_token == stop_tok {
                    break;
                }
            }
            // The next decode appends into slot `seq_len`, which is legal
            // while `seq_len < max_seq` (the last slot is usable).
            if group[0].cache.seq_len() >= self.entry.dims.max_seq {
                break;
            }
            let rows = self.decode_step(&mut group)?;
            let tok = sampler::greedy(&rows[0]);
            group[0].last_token = tok;
            group[0].tokens.push(tok);
        }
        Ok(group[0].generated().to_vec())
    }
}

/// Choose a compiled batch size: the largest ≤ `n`, else the smallest
/// (padding).
pub fn pick_batch(n: usize, avail: &[usize]) -> usize {
    debug_assert!(!avail.is_empty());
    avail
        .iter()
        .rev()
        .find(|&&b| b <= n)
        .or_else(|| avail.first())
        .copied()
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_batch_prefers_largest_fitting() {
        let avail = vec![1, 4];
        assert_eq!(pick_batch(1, &avail), 1);
        assert_eq!(pick_batch(3, &avail), 1);
        assert_eq!(pick_batch(4, &avail), 4);
        assert_eq!(pick_batch(9, &avail), 4);
    }

    #[test]
    fn pick_batch_pads_when_nothing_fits() {
        let avail = vec![4, 8];
        assert_eq!(pick_batch(2, &avail), 4);
    }
}
