//! Model engine: orchestrates the AOT graphs against the cache managers.
//!
//! * [`session`] — per-request generation state: token history plus one of
//!   the cache variants (MiKV mixed-precision manager / full-precision /
//!   oracle).
//! * [`assembly`] — [`assembly::StepArena`]: zero-allocation, delta-aware
//!   decode-step input assembly (dirty-row copies over reusable batch
//!   tensors), shared by the engine and the `perf_decode_assembly` bench.
//! * [`engine`] — [`engine::Engine`]: loads one model's artifact set,
//!   uploads weights once, and drives batched prefill/decode steps.
//! * [`sampler`] — greedy decoding (the paper evaluates with deterministic
//!   greedy decoding throughout).
//! * [`stub`] — artifact-free deterministic engine for protocol tests and
//!   the CI smoke run.

pub mod assembly;
pub mod engine;
pub mod sampler;
pub mod session;
pub mod stub;

pub use assembly::{AssemblyStats, StepArena};
pub use engine::{Engine, PrefillOutput};
pub use session::{CacheMode, FullCache, Session, SessionCache};
pub use stub::StubEngine;
