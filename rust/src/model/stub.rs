//! Deterministic stub engine: drives the full serving stack (coordinator,
//! sessions, caches, TCP protocol) without compiled artifacts or a PJRT
//! runtime.
//!
//! Used by the coordinator/protocol test suites and by the CI smoke run
//! (`cargo run --example client -- --stub`). Prefill and decode synthesize
//! seeded pseudo-random K/V and attention tensors and ingest them through
//! the **real** cache managers — so tier placement, pooled shadow blocks,
//! occupancy accounting and multi-turn re-ingest behave exactly as they do
//! under the real engine; only the model math is fake. Token sampling is
//! deterministic: the prefill token is a function of the prompt and each
//! decode step's argmax is `last_token + 1 (mod vocab)`, which makes
//! streamed-token assertions exact.

use crate::coordinator::StepEngine;
use crate::model::{Session, SessionCache};
use crate::runtime::ModelDims;
use crate::util::faults::{FaultPlan, FaultSite};
use crate::util::rng::{Pcg32, SplitMix64};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Default tensor-synthesis seed (kept stable so pre-sharding golden
/// values reproduce).
const DEFAULT_SEED: u64 = 0x57AB;

/// The stub engine (see module docs). Cheaply clonable: the sharded
/// runtime hands each worker its own [`StubEngine::fork`] with an
/// independent, deterministically derived tensor seed.
pub struct StubEngine {
    dims: ModelDims,
    /// Tensor-synthesis seed; token sampling is seed-independent, so
    /// streamed-token assertions stay exact across differently seeded
    /// workers.
    seed: u64,
    /// Artificial **per-session** decode cost: `decode_step` sleeps
    /// `decode_delay × batch_size`, modelling an engine whose per-token
    /// work is serialized on its own accelerator. Tests use it to cancel
    /// in-flight work deterministically instead of racing a
    /// microsecond-fast loop; the serving throughput bench uses it to make
    /// worker scaling measurable (N workers overlap N engines' delays).
    pub decode_delay: Duration,
    /// Fail every decode step (error-path and retirement tests).
    pub fail_decode: bool,
    /// Deterministic fault injection: probed at the top of every
    /// `decode_step` for the `engine_step_error` / `engine_step_panic`
    /// sites. The default (disabled) plan is a single `Option` check.
    /// Shared across clones and worker forks, so a chaos harness sees
    /// one global occurrence sequence.
    pub faults: FaultPlan,
    /// Host-side per-step cache work (tensor synthesis + ingest) of the
    /// most recent `decode_step`, in nanoseconds — the stub's analogue of
    /// the real engine's input-assembly time, so `assembly_us` plumbing is
    /// exercisable end to end without artifacts. Atomic (not `Cell`) so
    /// the engine stays `Sync` for the worker-factory closures.
    assembly_ns: AtomicU64,
}

// Manual Clone: each copy (and each worker fork) gets its own timing cell.
impl Clone for StubEngine {
    fn clone(&self) -> StubEngine {
        StubEngine {
            dims: self.dims.clone(),
            seed: self.seed,
            decode_delay: self.decode_delay,
            fail_decode: self.fail_decode,
            faults: self.faults.clone(),
            assembly_ns: AtomicU64::new(0),
        }
    }
}

impl StubEngine {
    pub fn new(dims: ModelDims) -> StubEngine {
        StubEngine {
            dims,
            seed: DEFAULT_SEED,
            decode_delay: Duration::ZERO,
            fail_decode: false,
            faults: FaultPlan::disabled(),
            assembly_ns: AtomicU64::new(0),
        }
    }

    /// A copy of this engine for worker `worker` of a sharded runtime:
    /// same dims/delay/failure knobs, independent deterministic tensor
    /// seed derived from this engine's seed.
    pub fn fork(&self, worker: usize) -> StubEngine {
        let mut sm = SplitMix64::new(self.seed ^ ((worker as u64 + 1) << 32));
        StubEngine {
            seed: sm.split(),
            ..self.clone()
        }
    }

    /// Tiny dimensions suitable for protocol/coordinator tests.
    pub fn test_dims(max_seq: usize) -> ModelDims {
        ModelDims {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_q_heads: 2,
            n_kv_heads: 2,
            d_head: 4,
            d_ff: 32,
            max_seq,
            quant_group: 2,
            params: 0,
        }
    }

    fn rng_for(&self, salt: u64) -> Pcg32 {
        Pcg32::new(self.seed ^ salt)
    }
}

impl StepEngine for StubEngine {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn prefill(
        &self,
        sessions: &mut [&mut Session],
        prompts: &[Vec<i64>],
    ) -> crate::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(sessions.len() == prompts.len());
        let planes = self.dims.planes();
        let d = self.dims.d_head;
        let vocab = self.dims.vocab;
        let mut rows = Vec::with_capacity(sessions.len());
        for (sess, prompt) in sessions.iter_mut().zip(prompts) {
            anyhow::ensure!(
                !prompt.is_empty() && prompt.len() <= self.dims.max_seq,
                "bad prompt length {}",
                prompt.len()
            );
            let t = prompt.len();
            let mut rng = self.rng_for(sess.id ^ (t as u64));
            let k: Vec<f32> = (0..planes * t * d).map(|_| rng.gen_normal() * 0.5).collect();
            let v: Vec<f32> = (0..planes * t * d).map(|_| rng.gen_normal() * 0.5).collect();
            match &mut sess.cache {
                SessionCache::Full(f) => f.ingest_prefill(t, &k, &v),
                SessionCache::Mikv(m) => {
                    let acc: Vec<f32> = (0..planes * t).map(|_| rng.gen_f32()).collect();
                    let qmax: Vec<f32> = (0..planes * d).map(|_| rng.gen_f32() + 0.5).collect();
                    let kmax: Vec<f32> = (0..planes * d).map(|_| rng.gen_f32() + 0.5).collect();
                    m.ingest_prefill(t, &k, &v, &acc, &qmax, &kmax);
                }
            }
            sess.tokens = prompt.clone();
            sess.prompt_len = t;
            // First sampled token: a deterministic function of the prompt.
            let tok = prompt.iter().sum::<i64>().rem_euclid(vocab as i64);
            sess.last_token = tok;
            sess.tokens.push(tok);
            let mut logits = vec![0.0f32; vocab];
            logits[tok as usize] = 1.0;
            rows.push(logits);
        }
        Ok(rows)
    }

    fn assembly_us_last(&self) -> Option<f64> {
        // lint: relaxed-ordering-audit-ok: monotonic telemetry cell read racily for stats only
        Some(self.assembly_ns.load(Ordering::Relaxed) as f64 / 1e3)
    }

    fn decode_step(&self, sessions: &mut [&mut Session]) -> crate::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(!self.fail_decode, "injected decode failure");
        if self.faults.should_fire(FaultSite::EngineStepPanic) {
            // Deliberate: models an engine bug taking the worker thread
            // down; scheduler supervision catches it and respawns.
            panic!("fault plan: injected decode panic");
        }
        if self.faults.should_fire(FaultSite::EngineStepError) {
            anyhow::bail!("fault plan: injected decode fault");
        }
        if self.decode_delay > Duration::ZERO && !sessions.is_empty() {
            // Per-session cost: this engine's work is serialized on its own
            // (emulated) accelerator, so a batch of B costs B × delay.
            std::thread::sleep(self.decode_delay * sessions.len() as u32);
        }
        // Timed below: the real host-side cache work (synthesis + ingest),
        // excluding the artificial sleep — the stub's `assembly_us`.
        let t0 = Instant::now();
        let planes = self.dims.planes();
        let (d, s, vocab) = (self.dims.d_head, self.dims.max_seq, self.dims.vocab);
        let mut rows = Vec::with_capacity(sessions.len());
        for sess in sessions.iter_mut() {
            let mut rng = self.rng_for(sess.id ^ ((sess.cache.seq_len() as u64) << 8));
            let k: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal() * 0.5).collect();
            let v: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal() * 0.5).collect();
            let attn_prev: Vec<f32> = (0..planes * s).map(|_| rng.gen_f32() * 0.1).collect();
            let attn_self: Vec<f32> = (0..planes).map(|_| rng.gen_f32() * 0.1).collect();
            sess.try_ingest_step(&k, &v, &attn_prev, &attn_self)?;
            // The next token deterministically follows the fed one.
            let tok = (sess.last_token + 1).rem_euclid(vocab as i64);
            let mut logits = vec![0.0f32; vocab];
            logits[tok as usize] = 1.0;
            rows.push(logits);
        }
        // lint: relaxed-ordering-audit-ok: stats-only telemetry; no reader orders against this store
        self.assembly_ns.store(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CompressionSpec;
    use crate::model::CacheMode;

    #[test]
    fn stub_prefill_and_decode_are_deterministic() {
        let dims = StubEngine::test_dims(16);
        let engine = StubEngine::new(dims.clone());
        let prompt = vec![1, 2, 3];
        let run = |id: u64| {
            let mode = CompressionSpec::mikv(0.5, "int4").resolve(&dims).unwrap();
            let mut sess = Session::new(id, &dims, mode).unwrap();
            {
                let mut group = [&mut sess];
                engine.prefill(&mut group, &[prompt.clone()]).unwrap();
            }
            for _ in 0..3 {
                let mut group = [&mut sess];
                let rows = engine.decode_step(&mut group).unwrap();
                let tok = crate::model::sampler::greedy(&rows[0]);
                group[0].last_token = tok;
                group[0].tokens.push(tok);
            }
            sess.generated().to_vec()
        };
        let a = run(7);
        let b = run(7);
        assert_eq!(a, b, "same id + prompt must reproduce");
        assert_eq!(a.len(), 4);
        // tokens follow the +1 (mod vocab) rule after the prefill sample
        assert_eq!(a[1], (a[0] + 1) % 32);
        assert_eq!(a[3], (a[2] + 1) % 32);
    }

    /// Worker forks are deterministic and independent: the same fork index
    /// reproduces the same tensors, different indexes diverge, and token
    /// sampling (prompt-sum prefill, +1 decode) is identical across forks.
    #[test]
    fn forks_are_deterministic_and_seed_independent_for_tokens() {
        let dims = StubEngine::test_dims(16);
        let base = StubEngine::new(dims.clone());
        let run = |engine: &StubEngine| {
            let mode = CompressionSpec::mikv(0.5, "int4").resolve(&dims).unwrap();
            let mut sess = Session::new(3, &dims, mode).unwrap();
            {
                let mut group = [&mut sess];
                engine.prefill(&mut group, &[vec![1, 2, 3]]).unwrap();
            }
            for _ in 0..2 {
                let mut group = [&mut sess];
                let rows = engine.decode_step(&mut group).unwrap();
                let tok = crate::model::sampler::greedy(&rows[0]);
                group[0].last_token = tok;
                group[0].tokens.push(tok);
            }
            let kv = match &sess.cache {
                SessionCache::Mikv(m) => m.effective_kv(0, 0).unwrap().0,
                _ => unreachable!(),
            };
            (sess.generated().to_vec(), kv)
        };
        let (tok_a, kv_a) = run(&base.fork(0));
        let (tok_a2, kv_a2) = run(&base.fork(0));
        let (tok_b, kv_b) = run(&base.fork(1));
        assert_eq!(tok_a, tok_a2, "same fork reproduces");
        assert_eq!(kv_a, kv_a2);
        assert_eq!(tok_a, tok_b, "token rule is seed-independent");
        assert_ne!(kv_a, kv_b, "different forks synthesize different KV");
    }

    #[test]
    fn stub_supports_full_cache_sessions() {
        let dims = StubEngine::test_dims(8);
        let engine = StubEngine::new(dims.clone());
        let mut sess = Session::new(1, &dims, CacheMode::Full).unwrap();
        {
            let mut group = [&mut sess];
            engine.prefill(&mut group, &[vec![4, 5]]).unwrap();
        }
        assert_eq!(sess.cache.seq_len(), 2);
        assert_eq!(sess.cache.occupancy().hi_slots, 2 * dims.planes() as u64);
    }
}
