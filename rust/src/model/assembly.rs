//! Zero-allocation, delta-aware decode-step input assembly.
//!
//! The innermost serving loop used to rebuild the decode graph's batch
//! host tensors from scratch on **every** step: 13 `vec![0.0; ..]`
//! allocations zero-filled to `b × planes × max_seq × width` and then
//! overwritten with each session's live rows. A [`StepArena`] replaces
//! that with buffers that live as long as the engine:
//!
//! * **Zero allocation** — the arena's buffers are sized once per
//!   `(batch, planes, max_seq)` shape and reused; a steady-state step
//!   performs no heap allocation at all (asserted by
//!   `benches/perf_decode_assembly.rs` with a counting global allocator).
//! * **Watermark zeroing** — instead of zero-filling whole tensors, each
//!   lane remembers how many rows it has ever filled (`live` watermark)
//!   and re-zeroes only the rows that shrank when a shorter session (or
//!   padding) takes the lane over.
//! * **Delta copies** — each cache tracks the shadow rows it touched since
//!   the engine last synchronized it ([`crate::kvcache::dirty`]). When a
//!   lane still holds the same session at the matching sync version, the
//!   step copies **only the dirty rows** (one appended row plus any
//!   demoted victims) instead of the whole `0..seq_len` prefix. Any
//!   mismatch — new session in the lane, missed take, prefill — falls back
//!   to a full rescatter of the live prefix, so the fast path is never
//!   load-bearing for correctness (property-tested below against a
//!   from-scratch reference).
//! * **Chunk-base lane keying** — a decode step whose group splits into
//!   several chunks assembles the chunk at group offset `i` into arena
//!   lanes `i..i + b` ([`assemble_mikv_at`] / [`assemble_full_at`]):
//!   chunks own disjoint lane ranges, so a stable multi-chunk group
//!   delta-patches every lane instead of the chunks evicting each other
//!   from the low lanes every step.
//!
//! The assembly entry points are free functions over `&mut Session` so the
//! perf bench and the equivalence tests can drive the exact engine path
//! without compiled artifacts or a PJRT runtime.

use super::session::{Session, SessionCache};
use crate::kvcache::dirty::MAX_TRACKED_ROWS;
use crate::runtime::ModelDims;

/// Cumulative assembly counters (reset with [`StepArena::reset_stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AssemblyStats {
    /// Assembly calls.
    pub steps: u64,
    /// Lanes refreshed via the dirty-row delta path.
    pub delta_lanes: u64,
    /// Lanes rebuilt via the full live-prefix rescatter.
    pub full_lanes: u64,
    /// Plane-rows copied (delta rows or live-prefix rows, × planes).
    pub rows_copied: u64,
    /// Bytes copied into the batch tensors.
    pub bytes_copied: u64,
    /// Bytes re-zeroed by the shrink watermarks.
    pub bytes_zeroed: u64,
    /// Buffer (re)shapes — arena allocations. 0 in steady state.
    pub grows: u64,
}

/// What one lane of the batch currently holds.
#[derive(Debug, Clone, Copy)]
struct Lane {
    /// The cached content may be delta-patched (false forces a rescatter).
    valid: bool,
    /// Session whose shadow this lane mirrors.
    sid: u64,
    /// The session cache's dirty-tracker version the lane is synced to.
    version: u64,
    /// Watermark: rows `0..live` may be nonzero; rows beyond are zero.
    live: usize,
}

const EMPTY_LANE: Lane = Lane {
    valid: false,
    sid: 0,
    version: 0,
    live: 0,
};

/// Reusable decode-step batch tensors (see module docs). One arena per
/// graph kind; block `i` is the `[b, planes, rows, widths[i]]` host tensor
/// for the graph's `i`-th cache input, in graph-input order.
pub struct StepArena {
    widths: Vec<usize>,
    /// Width of the per-lane `[planes, extra_width]` aux row (the MiKV
    /// balancer inverse; 0 when the graph has none). Fill value is 1.0.
    extra_width: usize,
    b: usize,
    planes: usize,
    rows: usize,
    /// `[b]` fed token per lane.
    pub token: Vec<i64>,
    /// `[b]` position (current seq_len) per lane.
    pub pos: Vec<i64>,
    blocks: Vec<Vec<f32>>,
    /// `[b, planes, extra_width]` aux input (identity-filled).
    pub extra: Vec<f32>,
    lanes: Vec<Lane>,
    /// Reusable dirty-row drain target (pre-reserved so takes never
    /// allocate).
    dirty_scratch: Vec<usize>,
    pub stats: AssemblyStats,
}

impl StepArena {
    /// An arena for cache blocks of the given per-row widths (graph-input
    /// order) plus an optional per-lane aux row.
    // lint: hot-path-alloc-free-ok(fn): one-time constructor; the per-step path reuses these buffers
    pub fn new(widths: &[usize], extra_width: usize) -> StepArena {
        StepArena {
            widths: widths.to_vec(),
            extra_width,
            b: 0,
            planes: 0,
            rows: 0,
            token: Vec::new(),
            pos: Vec::new(),
            blocks: vec![Vec::new(); widths.len()],
            extra: Vec::new(),
            lanes: Vec::new(),
            dirty_scratch: Vec::with_capacity(MAX_TRACKED_ROWS),
            stats: AssemblyStats::default(),
        }
    }

    /// Arena shaped for the `decode_mikv` graph: k_hi, v_hi, hi_mask,
    /// k_lo_codes, k_lo_scale, k_lo_zero, v_lo_codes, v_lo_scale,
    /// v_lo_zero, lo_mask — plus the `[planes, d]` balancer inverse aux.
    pub fn for_mikv(dims: &ModelDims) -> StepArena {
        let d = dims.d_head;
        let g = dims.n_groups();
        StepArena::new(&[d, d, 1, d, g, g, d, g, g, 1], d)
    }

    /// Arena shaped for the `decode_full` graph: k, v, mask.
    pub fn for_full(dims: &ModelDims) -> StepArena {
        let d = dims.d_head;
        StepArena::new(&[d, d, 1], 0)
    }

    pub fn n_blocks(&self) -> usize {
        self.widths.len()
    }

    /// Lanes currently allocated (grow-only high-water mark over the
    /// compiled batch sizes seen).
    pub fn lanes_allocated(&self) -> usize {
        self.b
    }

    /// Block `i`'s host tensor over all allocated lanes,
    /// `[lanes_allocated, planes, rows, widths[i]]`.
    // lint: panic-free-serving-ok(fn): i < widths.len() fixed by graph shape at construction
    pub fn block(&self, i: usize) -> &[f32] {
        &self.blocks[i]
    }

    /// The `b`-lane prefix of block `i` — what a chunk compiled at batch
    /// `b` uploads (the arena may hold more lanes than this chunk uses).
    // lint: panic-free-serving-ok(fn): i/b bounded by graph shape and ensure_shape
    pub fn block_prefix(&self, i: usize, b: usize) -> &[f32] {
        self.block_range(i, 0, b)
    }

    /// Lanes `base..base + b` of block `i` — what a chunk assembled at
    /// lane `base` uploads (the lane-major layout keeps any chunk's lanes
    /// contiguous, so a mid-arena chunk is still one slice).
    // lint: panic-free-serving-ok(fn): base + b bounded by ensure_shape for this chunk
    pub fn block_range(&self, i: usize, base: usize, b: usize) -> &[f32] {
        let stride = self.planes * self.rows * self.widths[i];
        &self.blocks[i][base * stride..(base + b) * stride]
    }

    /// The `b`-lane prefix of the token input.
    // lint: panic-free-serving-ok(fn): b <= allocated lanes per ensure_shape
    pub fn token_prefix(&self, b: usize) -> &[i64] {
        self.token_range(0, b)
    }

    /// Lanes `base..base + b` of the token input.
    // lint: panic-free-serving-ok(fn): base + b bounded by ensure_shape for this chunk
    pub fn token_range(&self, base: usize, b: usize) -> &[i64] {
        &self.token[base..base + b]
    }

    /// The `b`-lane prefix of the position input.
    // lint: panic-free-serving-ok(fn): b <= allocated lanes per ensure_shape
    pub fn pos_prefix(&self, b: usize) -> &[i64] {
        self.pos_range(0, b)
    }

    /// Lanes `base..base + b` of the position input.
    // lint: panic-free-serving-ok(fn): base + b bounded by ensure_shape for this chunk
    pub fn pos_range(&self, base: usize, b: usize) -> &[i64] {
        &self.pos[base..base + b]
    }

    /// The `b`-lane prefix of the aux input.
    // lint: panic-free-serving-ok(fn): b <= allocated lanes per ensure_shape
    pub fn extra_prefix(&self, b: usize) -> &[f32] {
        self.extra_range(0, b)
    }

    /// Lanes `base..base + b` of the aux input.
    // lint: panic-free-serving-ok(fn): base + b bounded by ensure_shape for this chunk
    pub fn extra_range(&self, base: usize, b: usize) -> &[f32] {
        let stride = self.planes * self.extra_width;
        &self.extra[base * stride..(base + b) * stride]
    }

    /// Host bytes the arena pins (buffers + bookkeeping).
    pub fn host_bytes(&self) -> usize {
        let f = std::mem::size_of::<f32>();
        self.blocks.iter().map(|b| b.capacity() * f).sum::<usize>()
            + self.extra.capacity() * f
            + (self.token.capacity() + self.pos.capacity()) * std::mem::size_of::<i64>()
            + self.lanes.capacity() * std::mem::size_of::<Lane>()
            + self.dirty_scratch.capacity() * std::mem::size_of::<usize>()
    }

    /// Forget every lane's cached content: the next assembly rebuilds each
    /// lane through the full-rescatter path (watermarks are kept, so the
    /// stale rows are still re-zeroed correctly).
    pub fn invalidate(&mut self) {
        for l in &mut self.lanes {
            l.valid = false;
        }
    }

    pub fn reset_stats(&mut self) {
        self.stats = AssemblyStats::default();
    }

    /// Size the buffers for at least `b` lanes of `(planes, rows)`. Lane
    /// capacity is **grow-only** and growth preserves existing lanes (the
    /// layout is lane-major, so appending lanes never moves earlier ones)
    /// — a step that alternates between compiled batch sizes keeps its
    /// delta lanes instead of reshaping every chunk. A `(planes, rows)`
    /// change (a different model's dims) rebuilds from scratch. The
    /// steady-state call is a no-op.
    pub fn ensure_shape(&mut self, b: usize, planes: usize, rows: usize) {
        let reshape = planes != self.planes || rows != self.rows;
        if !reshape && b <= self.b {
            return;
        }
        self.stats.grows += 1;
        if reshape {
            self.planes = planes;
            self.rows = rows;
            for buf in &mut self.blocks {
                buf.clear();
            }
            self.extra.clear();
            self.token.clear();
            self.pos.clear();
            self.lanes.clear();
            self.b = 0;
        }
        let target = b.max(self.b);
        for (buf, &w) in self.blocks.iter_mut().zip(&self.widths) {
            buf.resize(target * planes * rows * w, 0.0);
        }
        self.extra.resize(target * planes * self.extra_width, 1.0);
        self.token.resize(target, 0);
        self.pos.resize(target, 0);
        self.lanes.resize(target, EMPTY_LANE);
        self.b = target;
    }

    /// Zero rows `from..to` of every plane of `lane` across all blocks.
    // lint: panic-free-serving-ok(fn): lane/rows bounded by ensure_shape before any scatter
    fn zero_lane_rows(&mut self, lane: usize, from: usize, to: usize) {
        if from >= to {
            return;
        }
        let (planes, rows) = (self.planes, self.rows);
        for (buf, &w) in self.blocks.iter_mut().zip(&self.widths) {
            for p in 0..planes {
                let base = (lane * planes + p) * rows;
                buf[(base + from) * w..(base + to) * w].fill(0.0);
            }
            self.stats.bytes_zeroed += ((to - from) * w * planes * 4) as u64;
        }
    }

    /// Full rescatter of block `i`, lane `lane`: copy the live `0..live`
    /// prefix of every plane from a session block with row stride `cap`.
    // lint: panic-free-serving-ok(fn): offsets derived from arena shape; src length checked by caller
    fn copy_rows_full(&mut self, i: usize, lane: usize, src: &[f32], cap: usize, live: usize) {
        let w = self.widths[i];
        let (planes, rows) = (self.planes, self.rows);
        let buf = &mut self.blocks[i];
        for p in 0..planes {
            let d0 = (lane * planes + p) * rows * w;
            let s0 = p * cap * w;
            buf[d0..d0 + live * w].copy_from_slice(&src[s0..s0 + live * w]);
        }
        self.stats.bytes_copied += (planes * live * w * 4) as u64;
    }

    /// Delta patch of block `i`, lane `lane`: copy only `rows_list` rows of
    /// every plane.
    // lint: panic-free-serving-ok(fn): dirty rows are < cap by DirtyTracker contract
    fn copy_rows_delta(
        &mut self,
        i: usize,
        lane: usize,
        src: &[f32],
        cap: usize,
        rows_list: &[usize],
    ) {
        let w = self.widths[i];
        let (planes, rows) = (self.planes, self.rows);
        let buf = &mut self.blocks[i];
        for p in 0..planes {
            let dbase = (lane * planes + p) * rows;
            let sbase = p * cap;
            for &r in rows_list {
                let d0 = (dbase + r) * w;
                let s0 = (sbase + r) * w;
                buf[d0..d0 + w].copy_from_slice(&src[s0..s0 + w]);
            }
        }
        self.stats.bytes_copied += (planes * rows_list.len() * w * 4) as u64;
    }

    /// Turn `lane` into a zero padding lane (stale rows re-zeroed up to the
    /// watermark, aux row reset to the identity fill).
    // lint: panic-free-serving-ok(fn): lane comes from the live lane map, always allocated
    fn retire_lane(&mut self, lane: usize) {
        let prev = self.lanes[lane];
        self.zero_lane_rows(lane, 0, prev.live);
        if self.extra_width > 0 {
            let e0 = lane * self.planes * self.extra_width;
            self.extra[e0..e0 + self.planes * self.extra_width].fill(1.0);
        }
        self.token[lane] = 0;
        self.pos[lane] = 0;
        self.lanes[lane] = EMPTY_LANE;
    }

    /// The per-lane delta/full protocol shared by [`assemble_mikv`] and
    /// [`assemble_full`]: patch the lane with the drained dirty rows when
    /// the `(session, sync-version)` handshake holds, otherwise re-zero the
    /// shrunk tail and rescatter the live prefix (and refresh the aux row,
    /// which only changes on `take.all` mutations). `srcs` are the session
    /// blocks in block order, row stride `cap`; the dirty rows sit in
    /// `self.dirty_scratch` (drained there by the caller's take).
    // lint: panic-free-serving-ok(fn): lane/block offsets bounded by ensure_shape for this batch
    fn fill_lane(
        &mut self,
        lane: usize,
        sid: u64,
        take: crate::kvcache::DirtyTake,
        srcs: &[&[f32]],
        cap: usize,
        live: usize,
        aux: Option<&[f32]>,
    ) {
        debug_assert_eq!(srcs.len(), self.widths.len());
        let prev = self.lanes[lane];
        let delta_ok = prev.valid
            && prev.sid == sid
            && prev.version == take.prev_version
            && !take.all
            && live >= prev.live;
        if delta_ok {
            let dirty = std::mem::take(&mut self.dirty_scratch);
            debug_assert!(dirty.iter().all(|&r| r < live));
            for (i, src) in srcs.iter().enumerate() {
                self.copy_rows_delta(i, lane, src, cap, &dirty);
            }
            self.stats.delta_lanes += 1;
            self.stats.rows_copied += (dirty.len() * self.planes) as u64;
            self.dirty_scratch = dirty;
            // The aux row (balancer inverse) only changes at prefill, which
            // forces `take.all`: nothing to refresh on the delta path.
        } else {
            self.zero_lane_rows(lane, live, prev.live);
            for (i, src) in srcs.iter().enumerate() {
                self.copy_rows_full(i, lane, src, cap, live);
            }
            if let Some(aux) = aux {
                debug_assert_eq!(aux.len(), self.planes * self.extra_width);
                let e0 = lane * self.planes * self.extra_width;
                self.extra[e0..e0 + aux.len()].copy_from_slice(aux);
                self.stats.bytes_copied += (aux.len() * 4) as u64;
            }
            self.stats.full_lanes += 1;
            self.stats.rows_copied += (live * self.planes) as u64;
        }
        self.lanes[lane] = Lane {
            valid: true,
            sid,
            version: take.version,
            live,
        };
    }
}

/// Assemble the `decode_mikv` batch inputs for `sessions` into `arena`
/// (compiled batch size `b`; lanes `sessions.len()..b` become zero
/// padding). Lanes whose cached `(session, sync-version)` matches take the
/// dirty-row delta path; everything else full-rescatters the live prefix.
pub fn assemble_mikv(
    arena: &mut StepArena,
    dims: &ModelDims,
    b: usize,
    sessions: &mut [&mut Session],
) -> crate::Result<()> {
    assemble_mikv_at(arena, dims, 0, b, sessions)
}

/// [`assemble_mikv`] keyed to lane `base`: the chunk occupies arena lanes
/// `base..base + b`. A multi-chunk `decode_step` passes each chunk's
/// offset in the decode group as `base`, so every chunk owns a disjoint
/// lane range and a stable group keeps the dirty-row delta path on every
/// lane instead of chunks evicting each other from the low lanes.
// lint: panic-free-serving-ok(fn): per-session views validated against dims before scatter
pub fn assemble_mikv_at(
    arena: &mut StepArena,
    dims: &ModelDims,
    base: usize,
    b: usize,
    sessions: &mut [&mut Session],
) -> crate::Result<()> {
    let planes = dims.planes();
    let s = dims.max_seq;
    let ng = dims.n_groups();
    anyhow::ensure!(sessions.len() <= b, "chunk of {} > batch {b}", sessions.len());
    arena.ensure_shape(base + b, planes, s);
    arena.stats.steps += 1;

    for (k, sess) in sessions.iter_mut().enumerate() {
        let lane = base + k;
        let sid = sess.id;
        arena.token[lane] = sess.last_token;
        arena.pos[lane] = sess.cache.seq_len() as i64;
        let m = match &mut sess.cache {
            SessionCache::Mikv(m) => m,
            _ => anyhow::bail!("session {sid} is not MiKV"),
        };
        anyhow::ensure!(
            m.groups() == ng,
            "session {sid}: cache has {} scale groups per token, graph expects {ng}",
            m.groups()
        );
        let take = m.take_dirty_into(&mut arena.dirty_scratch);
        let views = m.decode_views();
        let (cap, live) = (views.cap, views.seq_len.min(s));
        let srcs: [&[f32]; 10] = [
            views.k_hi,
            views.v_hi,
            views.hi_mask,
            views.k_lo_codes,
            views.k_lo_scale,
            views.k_lo_zero,
            views.v_lo_codes,
            views.v_lo_scale,
            views.v_lo_zero,
            views.lo_mask,
        ];
        arena.fill_lane(lane, sid, take, &srcs, cap, live, Some(views.inv_balancer));
    }
    for lane in base + sessions.len()..base + b {
        arena.retire_lane(lane);
    }
    Ok(())
}

/// Assemble the `decode_full` batch inputs (k, v, mask) for full/oracle
/// sessions into `arena`, with the same delta/full lane protocol as
/// [`assemble_mikv`].
pub fn assemble_full(
    arena: &mut StepArena,
    dims: &ModelDims,
    b: usize,
    sessions: &mut [&mut Session],
) -> crate::Result<()> {
    assemble_full_at(arena, dims, 0, b, sessions)
}

/// [`assemble_full`] keyed to lane `base` — see [`assemble_mikv_at`].
// lint: panic-free-serving-ok(fn): per-session views validated against dims before scatter
pub fn assemble_full_at(
    arena: &mut StepArena,
    dims: &ModelDims,
    base: usize,
    b: usize,
    sessions: &mut [&mut Session],
) -> crate::Result<()> {
    let planes = dims.planes();
    let s = dims.max_seq;
    anyhow::ensure!(sessions.len() <= b, "chunk of {} > batch {b}", sessions.len());
    arena.ensure_shape(base + b, planes, s);
    arena.stats.steps += 1;

    for (k, sess) in sessions.iter_mut().enumerate() {
        let lane = base + k;
        let sid = sess.id;
        arena.token[lane] = sess.last_token;
        arena.pos[lane] = sess.cache.seq_len() as i64;
        let f = match &mut sess.cache {
            SessionCache::Full(f) => f,
            _ => anyhow::bail!("session {sid} is not Full/Oracle"),
        };
        let take = f.take_dirty_into(&mut arena.dirty_scratch);
        // FullCache blocks are dense at `max_seq` stride already.
        let (cap, live) = (s, f.seq_len.min(s));
        let srcs: [&[f32]; 3] = [&f.k, &f.v, &f.mask];
        arena.fill_lane(lane, sid, take, &srcs, cap, live, None);
    }
    for lane in base + sessions.len()..base + b {
        arena.retire_lane(lane);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{CacheMode, Session};
    use crate::quant::Precision;
    use crate::util::prop::{forall, Config};
    use crate::util::rng::Pcg32;

    fn dims(max_seq: usize) -> ModelDims {
        ModelDims {
            vocab: 32,
            d_model: 32,
            n_layers: 2,
            n_q_heads: 2,
            n_kv_heads: 2,
            d_head: 8,
            d_ff: 32,
            max_seq,
            // n_groups() must match the MiKV lo tier's head_dim/2 grouping
            quant_group: 4,
            params: 0,
        }
    }

    fn mikv_session(id: u64, d: &ModelDims, prompt_len: usize, rng: &mut Pcg32) -> Session {
        let mode = CacheMode::mikv(d, 0.25, Precision::Int4);
        let mut sess = Session::new(id, d, mode).unwrap();
        prefill(&mut sess, d, prompt_len, rng);
        sess
    }

    /// A MiKV session with the lo→hi promotion pass enabled (aggressive
    /// knobs so promotions actually fire under the test workloads).
    fn mikv_promoting_session(
        id: u64,
        d: &ModelDims,
        prompt_len: usize,
        rng: &mut Pcg32,
    ) -> Session {
        let mut mode = CacheMode::mikv(d, 0.25, Precision::Int4);
        if let CacheMode::Mikv { cfg, .. } = &mut mode {
            cfg.promotion = Some(crate::kvcache::PromotionConfig {
                max_per_step: 2,
                min_residency: 1,
                promote_margin: 1.1,
            });
        }
        let mut sess = Session::new(id, d, mode).unwrap();
        prefill(&mut sess, d, prompt_len, rng);
        sess
    }

    /// Like [`step`], but with the attention row concentrated on one slot
    /// (drives the re-access EMA so the promotion pass fires).
    fn step_hot(sess: &mut Session, d: &ModelDims, hot: usize, rng: &mut Pcg32) {
        let planes = d.planes();
        let dh = d.d_head;
        let k: Vec<f32> = (0..planes * dh).map(|_| rng.gen_normal()).collect();
        let v: Vec<f32> = (0..planes * dh).map(|_| rng.gen_normal()).collect();
        let mut ap = vec![0.001f32; planes * d.max_seq];
        for p in 0..planes {
            ap[p * d.max_seq + hot] = 0.9;
        }
        let asf: Vec<f32> = (0..planes).map(|_| rng.gen_f32() * 0.1).collect();
        sess.try_ingest_step(&k, &v, &ap, &asf).unwrap();
        sess.last_token = (sess.last_token + 1) % 32;
        sess.tokens.push(sess.last_token);
    }

    fn prefill(sess: &mut Session, d: &ModelDims, t: usize, rng: &mut Pcg32) {
        let planes = d.planes();
        let dh = d.d_head;
        let k: Vec<f32> = (0..planes * t * dh).map(|_| rng.gen_normal()).collect();
        let v: Vec<f32> = (0..planes * t * dh).map(|_| rng.gen_normal()).collect();
        match &mut sess.cache {
            SessionCache::Mikv(m) => {
                let acc: Vec<f32> = (0..planes * t).map(|_| rng.gen_f32()).collect();
                let qmax: Vec<f32> = (0..planes * dh).map(|_| rng.gen_f32() + 0.5).collect();
                let kmax: Vec<f32> = (0..planes * dh).map(|_| rng.gen_f32() + 0.5).collect();
                m.ingest_prefill(t, &k, &v, &acc, &qmax, &kmax);
            }
            SessionCache::Full(f) => f.ingest_prefill(t, &k, &v),
        }
        sess.prompt_len = t;
        sess.tokens = vec![1; t];
        sess.last_token = (t % 7) as i64;
    }

    fn step(sess: &mut Session, d: &ModelDims, rng: &mut Pcg32) {
        let planes = d.planes();
        let dh = d.d_head;
        let k: Vec<f32> = (0..planes * dh).map(|_| rng.gen_normal()).collect();
        let v: Vec<f32> = (0..planes * dh).map(|_| rng.gen_normal()).collect();
        let ap: Vec<f32> = (0..planes * d.max_seq).map(|_| rng.gen_f32() * 0.1).collect();
        let asf: Vec<f32> = (0..planes).map(|_| rng.gen_f32() * 0.1).collect();
        sess.try_ingest_step(&k, &v, &ap, &asf).unwrap();
        sess.last_token = (sess.last_token + 1) % 32;
        sess.tokens.push(sess.last_token);
    }

    /// From-scratch reference: what the pre-arena engine built each step
    /// (fresh zero-filled tensors + live-prefix scatter). The arena's
    /// buffers must be bit-identical to this after every assembly, no
    /// matter which lanes took the delta path.
    fn expected_mikv(
        d: &ModelDims,
        b: usize,
        sessions: &[&mut Session],
    ) -> (Vec<i64>, Vec<i64>, Vec<Vec<f32>>, Vec<f32>) {
        let planes = d.planes();
        let (s, dh) = (d.max_seq, d.d_head);
        let ng = d.n_groups();
        let widths = [dh, dh, 1, dh, ng, ng, dh, ng, ng, 1];
        let mut token = vec![0i64; b];
        let mut pos = vec![0i64; b];
        let mut blocks: Vec<Vec<f32>> = widths
            .iter()
            .map(|w| vec![0.0f32; b * planes * s * w])
            .collect();
        let mut extra = vec![1.0f32; b * planes * dh];
        for (lane, sess) in sessions.iter().enumerate() {
            token[lane] = sess.last_token;
            pos[lane] = sess.cache.seq_len() as i64;
            let m = match &sess.cache {
                SessionCache::Mikv(m) => m,
                _ => unreachable!(),
            };
            let views = m.decode_views();
            let (cap, live) = (views.cap, views.seq_len.min(s));
            let srcs: [&[f32]; 10] = [
                views.k_hi,
                views.v_hi,
                views.hi_mask,
                views.k_lo_codes,
                views.k_lo_scale,
                views.k_lo_zero,
                views.v_lo_codes,
                views.v_lo_scale,
                views.v_lo_zero,
                views.lo_mask,
            ];
            for ((dst, src), &w) in blocks.iter_mut().zip(srcs.iter()).zip(widths.iter()) {
                for p in 0..planes {
                    let d0 = (lane * planes + p) * s * w;
                    let s0 = p * cap * w;
                    dst[d0..d0 + live * w].copy_from_slice(&src[s0..s0 + live * w]);
                }
            }
            extra[lane * planes * dh..(lane + 1) * planes * dh]
                .copy_from_slice(views.inv_balancer);
        }
        (token, pos, blocks, extra)
    }

    fn assert_arena_matches(
        arena: &StepArena,
        expect: &(Vec<i64>, Vec<i64>, Vec<Vec<f32>>, Vec<f32>),
        label: &str,
    ) {
        assert_eq!(arena.token, expect.0, "{label}: token");
        assert_eq!(arena.pos, expect.1, "{label}: pos");
        for (i, want) in expect.2.iter().enumerate() {
            let got = arena.block(i);
            assert_eq!(got.len(), want.len(), "{label}: block {i} len");
            for (j, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                assert!(
                    g.to_bits() == w.to_bits(),
                    "{label}: block {i} elem {j}: {g} != {w}"
                );
            }
        }
        assert_eq!(arena.extra, expect.3, "{label}: extra");
    }

    /// The delta-path equivalence property (tentpole acceptance): after
    /// arbitrary admit/observe/demote/**promote**/append activity,
    /// delta-assembled batch tensors are bit-identical to a full rescatter
    /// — including lane-shrink re-zeroing when a shorter session takes
    /// over a lane, padding-lane retirement, and the lane-migration
    /// fallback. Half the sessions run with the promotion pass enabled and
    /// concentrated attention, so the promote/swap dirty rows are part of
    /// the delta under test.
    #[test]
    fn property_delta_assembly_matches_full_rescatter() {
        forall(Config::default().cases(25).name("delta assembly"), |rng| {
            let d = dims(48);
            let n = 1 + rng.gen_below(3) as usize;
            let b = n + rng.gen_below(2) as usize; // sometimes padding lanes
            let mut sessions: Vec<Session> = (0..n)
                .map(|i| {
                    let t = 2 + rng.gen_below(12) as usize;
                    if rng.gen_bool(0.5) {
                        mikv_promoting_session(i as u64 + 1, &d, t, rng)
                    } else {
                        mikv_session(i as u64 + 1, &d, t, rng)
                    }
                })
                .collect();
            let mut arena = StepArena::for_mikv(&d);

            let steps = 2 + rng.gen_below(8) as usize;
            for stepno in 0..steps {
                // occasionally shuffle the lane assignment (migration +
                // shrink edges: a shorter session can land on a lane that
                // held a longer one)
                if rng.gen_bool(0.3) {
                    rng.shuffle(&mut sessions);
                }
                for sess in sessions.iter_mut() {
                    if sess.cache.seq_len() < d.max_seq {
                        if rng.gen_bool(0.5) {
                            let hot = rng.gen_below(sess.cache.seq_len() as u32) as usize;
                            step_hot(sess, &d, hot, rng);
                        } else {
                            step(sess, &d, rng);
                        }
                    }
                }
                let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
                assemble_mikv(&mut arena, &d, b, &mut refs)
                    .map_err(|e| format!("assemble failed: {e}"))?;
                let expect = expected_mikv(&d, b, &refs);
                assert_arena_matches(&arena, &expect, &format!("step {stepno}"));
            }
            // the fast path must actually fire on quiet steps
            if steps >= 4 {
                crate::prop_assert!(
                    arena.stats.delta_lanes + arena.stats.full_lanes > 0,
                    "no lanes assembled?"
                );
            }
            Ok(())
        });
    }

    /// Multi-chunk decode shape: a group larger than the compiled batch
    /// splits into chunks assembled at their group offsets
    /// ([`assemble_mikv_at`]). The assembled lanes must be bit-identical
    /// to the from-scratch reference over the whole group, and — because
    /// each chunk owns a disjoint lane range — EVERY lane of a stable
    /// group must take the delta path after first sight (the old
    /// lane-per-chunk indexing rescattered the overlap every step).
    #[test]
    fn property_multi_chunk_assembly_bit_identical_and_delta() {
        forall(Config::default().cases(20).name("multi-chunk assembly"), |rng| {
            let d = dims(48);
            let n = 3 + rng.gen_below(4) as usize; // group of 3..=6
            let c = 1 + rng.gen_below(n as u32 - 1) as usize; // first chunk
            let mut sessions: Vec<Session> = (0..n)
                .map(|i| {
                    let t = 2 + rng.gen_below(10) as usize;
                    mikv_session(i as u64 + 1, &d, t, rng)
                })
                .collect();
            let mut arena = StepArena::for_mikv(&d);

            let steps = 3 + rng.gen_below(4) as usize;
            for stepno in 0..steps {
                for sess in sessions.iter_mut() {
                    step(sess, &d, rng);
                }
                {
                    let (head, tail) = sessions.split_at_mut(c);
                    let mut refs: Vec<&mut Session> = head.iter_mut().collect();
                    assemble_mikv_at(&mut arena, &d, 0, c, &mut refs)
                        .map_err(|e| format!("chunk 1: {e}"))?;
                    let mut refs: Vec<&mut Session> = tail.iter_mut().collect();
                    assemble_mikv_at(&mut arena, &d, c, n - c, &mut refs)
                        .map_err(|e| format!("chunk 2: {e}"))?;
                }
                let refs: Vec<&mut Session> = sessions.iter_mut().collect();
                let expect = expected_mikv(&d, n, &refs);
                assert_arena_matches(&arena, &expect, &format!("multi-chunk step {stepno}"));
            }
            crate::prop_assert!(
                arena.stats.full_lanes == n as u64,
                "only first sight rescatters: {} full lanes for group of {n}",
                arena.stats.full_lanes
            );
            crate::prop_assert!(
                arena.stats.delta_lanes == (n * (steps - 1)) as u64,
                "every lane of every later step deltas: {} != {}",
                arena.stats.delta_lanes,
                n * (steps - 1)
            );
            Ok(())
        });
    }

    /// Deterministic delta-path exercise: steady lanes use the delta path,
    /// a lane migration falls back to full, and a padding lane left behind
    /// by a retired session is re-zeroed.
    #[test]
    fn delta_full_and_padding_transitions() {
        let d = dims(64);
        let mut rng = Pcg32::new(31);
        let mut a = mikv_session(1, &d, 10, &mut rng);
        let mut b_sess = mikv_session(2, &d, 4, &mut rng);
        let mut arena = StepArena::for_mikv(&d);

        // step 1: both lanes full (first sight)
        {
            let mut refs = [&mut a, &mut b_sess];
            assemble_mikv(&mut arena, &d, 2, &mut refs).unwrap();
        }
        assert_eq!(arena.stats.full_lanes, 2);
        assert_eq!(arena.stats.delta_lanes, 0);

        // step 2: append to both → both lanes delta
        step(&mut a, &d, &mut rng);
        step(&mut b_sess, &d, &mut rng);
        {
            let mut refs = [&mut a, &mut b_sess];
            assemble_mikv(&mut arena, &d, 2, &mut refs).unwrap();
            let expect = expected_mikv(&d, 2, &refs);
            assert_arena_matches(&arena, &expect, "steady delta");
        }
        assert_eq!(arena.stats.delta_lanes, 2, "steady lanes take the delta path");

        // step 3: swap lanes → both full (lane-migration fallback); the
        // shorter session lands on the longer session's lane (shrink zeroing)
        {
            let mut refs = [&mut b_sess, &mut a];
            assemble_mikv(&mut arena, &d, 2, &mut refs).unwrap();
            let expect = expected_mikv(&d, 2, &refs);
            assert_arena_matches(&arena, &expect, "after swap");
        }
        assert_eq!(arena.stats.delta_lanes, 2, "no delta on migrated lanes");
        assert_eq!(arena.stats.full_lanes, 4);

        // step 4: one session retires → its lane becomes padding and is
        // fully re-zeroed; the surviving session keeps its (new) lane and
        // goes back to delta
        step(&mut b_sess, &d, &mut rng);
        {
            let mut refs = [&mut b_sess];
            assemble_mikv(&mut arena, &d, 2, &mut refs).unwrap();
            let expect = expected_mikv(&d, 2, &refs);
            assert_arena_matches(&arena, &expect, "after retirement");
        }
        assert_eq!(arena.stats.delta_lanes, 3);

        // invalidate() forces full without losing correctness
        step(&mut b_sess, &d, &mut rng);
        arena.invalidate();
        {
            let mut refs = [&mut b_sess];
            assemble_mikv(&mut arena, &d, 2, &mut refs).unwrap();
            let expect = expected_mikv(&d, 2, &refs);
            assert_arena_matches(&arena, &expect, "after invalidate");
        }
        assert_eq!(arena.stats.full_lanes, 5);
    }

    /// Promotion mutations ride the delta path: a session whose workload
    /// keeps promoting (and swap-demoting) stays bit-correct against the
    /// from-scratch reference WITHOUT ever falling back to a full
    /// rescatter — the promote/swap rows are covered by the dirty list.
    #[test]
    fn promotion_rows_ride_the_delta_path() {
        let d = dims(64);
        let mut rng = Pcg32::new(37);
        let mut sess = mikv_promoting_session(1, &d, 12, &mut rng);
        // A slot that starts in the lo tier of plane 0 becomes the hot one.
        let hot = {
            let m = match &sess.cache {
                SessionCache::Mikv(m) => m,
                _ => unreachable!(),
            };
            (0..12)
                .find(|&s| m.placement(0, s) == crate::kvcache::Placement::Lo)
                .expect("ratio 0.25 leaves lo slots")
        };
        let mut arena = StepArena::for_mikv(&d);
        {
            let mut refs = [&mut sess];
            assemble_mikv(&mut arena, &d, 1, &mut refs).unwrap();
        }
        for stepno in 0..6 {
            step_hot(&mut sess, &d, hot, &mut rng);
            let mut refs = [&mut sess];
            assemble_mikv(&mut arena, &d, 1, &mut refs).unwrap();
            let expect = expected_mikv(&d, 1, &refs);
            assert_arena_matches(&arena, &expect, &format!("promote step {stepno}"));
        }
        assert_eq!(arena.stats.full_lanes, 1, "only first sight rescatters");
        assert_eq!(arena.stats.delta_lanes, 6, "promotion stays on the delta path");
        let stats = match &sess.cache {
            SessionCache::Mikv(m) => m.promotion_stats(),
            _ => unreachable!(),
        };
        assert!(stats.promotions > 0, "the workload must actually promote");
    }

    /// Full/oracle-cache assembly: same protocol over the dense blocks.
    #[test]
    fn assemble_full_matches_reference() {
        let d = dims(32);
        let mut rng = Pcg32::new(33);
        let mut sess = Session::new(5, &d, CacheMode::Full).unwrap();
        prefill(&mut sess, &d, 6, &mut rng);
        let mut arena = StepArena::for_full(&d);
        let planes = d.planes();
        let (s, dh) = (d.max_seq, d.d_head);

        for stepno in 0..4 {
            step(&mut sess, &d, &mut rng);
            {
                let mut refs = [&mut sess];
                assemble_full(&mut arena, &d, 2, &mut refs).unwrap();
            }
            let f = match &sess.cache {
                SessionCache::Full(f) => f,
                _ => unreachable!(),
            };
            // reference: lane 0 = the dense blocks verbatim, lane 1 zero
            let srcs: [(&[f32], usize); 3] = [(&f.k, dh), (&f.v, dh), (&f.mask, 1)];
            for (i, (src, w)) in srcs.iter().enumerate() {
                let w = *w;
                let got = arena.block(i);
                assert_eq!(got.len(), 2 * planes * s * w);
                assert_eq!(&got[..planes * s * w], *src, "step {stepno} block {i}");
                assert!(
                    got[planes * s * w..].iter().all(|&x| x == 0.0),
                    "step {stepno} block {i}: padding lane dirty"
                );
            }
            assert_eq!(arena.pos[0], f.seq_len as i64);
        }
        assert!(arena.stats.delta_lanes >= 3, "full-cache lanes delta after first step");
    }

    /// Lane capacity is grow-only and growth preserves cached lanes: a
    /// step alternating between compiled batch sizes keeps its deltas and
    /// uploads b-lane prefixes of the wider buffers.
    #[test]
    fn lane_capacity_grows_without_losing_cached_lanes() {
        let d = dims(64);
        let mut rng = Pcg32::new(35);
        let mut a = mikv_session(1, &d, 8, &mut rng);
        let mut b_sess = mikv_session(2, &d, 8, &mut rng);
        let mut arena = StepArena::for_mikv(&d);

        {
            let mut refs = [&mut a];
            assemble_mikv(&mut arena, &d, 1, &mut refs).unwrap();
        }
        assert_eq!(arena.lanes_allocated(), 1);

        // grow to b=2: lane 0's cached content survives and stays delta
        step(&mut a, &d, &mut rng);
        step(&mut b_sess, &d, &mut rng);
        {
            let mut refs = [&mut a, &mut b_sess];
            assemble_mikv(&mut arena, &d, 2, &mut refs).unwrap();
            let expect = expected_mikv(&d, 2, &refs);
            assert_arena_matches(&arena, &expect, "after growth");
        }
        assert_eq!(arena.lanes_allocated(), 2);
        assert_eq!(arena.stats.delta_lanes, 1, "lane 0 survived the growth");

        // back to b=1: prefix upload out of the wider buffer, lane 0 delta
        step(&mut a, &d, &mut rng);
        {
            let mut refs = [&mut a];
            assemble_mikv(&mut arena, &d, 1, &mut refs).unwrap();
        }
        assert_eq!(arena.stats.delta_lanes, 2);
        assert_eq!(arena.lanes_allocated(), 2, "capacity never shrinks");
        assert_eq!(arena.block_prefix(0, 1).len(), arena.block(0).len() / 2);
        assert_eq!(arena.token_prefix(1).len(), 1);
    }

    /// Steady state never reallocates: one grow at first shape, then none,
    /// and the per-step copy volume on the delta path is bounded by the
    /// dirty rows, far below the live prefix.
    #[test]
    fn arena_steady_state_does_not_grow_and_copies_little() {
        let d = dims(64);
        let mut rng = Pcg32::new(34);
        let mut sess = mikv_session(9, &d, 40, &mut rng);
        let mut arena = StepArena::for_mikv(&d);
        {
            let mut refs = [&mut sess];
            assemble_mikv(&mut arena, &d, 1, &mut refs).unwrap();
        }
        assert_eq!(arena.stats.grows, 1);
        let full_bytes = arena.stats.bytes_copied;

        arena.reset_stats();
        for _ in 0..8 {
            step(&mut sess, &d, &mut rng);
            let mut refs = [&mut sess];
            assemble_mikv(&mut arena, &d, 1, &mut refs).unwrap();
        }
        assert_eq!(arena.stats.grows, 0, "steady state never reshapes");
        assert_eq!(arena.stats.full_lanes, 0, "steady state never rescatters");
        assert_eq!(arena.stats.delta_lanes, 8);
        let delta_bytes_per_step = arena.stats.bytes_copied / 8;
        assert!(
            delta_bytes_per_step * 5 <= full_bytes,
            "delta copies {delta_bytes_per_step} B/step vs {full_bytes} B full"
        );
    }
}
