//! Token importance policies.
//!
//! MiKV is policy-agnostic (paper Fig. 4: "MiKV can apply the token
//! importance policies proposed in existing approaches"): the policy decides
//! *which* tokens sit in the high-precision importance cache; MiKV decides
//! what happens to the rest (retain quantized vs. evict).
//!
//! * [`H2oPolicy`] — accumulated attention ("heavy hitters", Zhang et al.
//!   2023): a slot's importance is the sum of attention it has received
//!   from every query so far, seeded by the prefill attention column-sums.
//! * [`LocalPolicy`] — recency (StreamingLLM / window attention style):
//!   newer is more important.
//! * [`RandomPolicy`] — uniformly random importance; the ablation control.
//! * [`LagKvPolicy`] — lag-relative importance from KV statistics only
//!   (LagKV, PAPERS.md): a slot's K/V rows are min-max normalized against a
//!   trailing window of recent rows and scored by their channel-wise spread.
//!   Consumes [`ImportancePolicy::observe_kv`] exclusively — no attention
//!   plumbing — so it ranks identically under engines that never surface
//!   attention rows (contract-tested below).
//!
//! The **oracle** policy of paper Fig. 3b is not an online policy — it
//! computes the full-cache attention map first and imposes top-k sparsity
//! post-attention. It therefore lives in the decode graph itself
//! (`decode_full`'s `oracle_k` input), not behind this trait.
//!
//! Besides the lifetime [`ImportancePolicy::score`], policies may expose a
//! **re-access** signal ([`ImportancePolicy::reaccess`]) — an EMA of the
//! attention a slot received over recent decode steps — which the cache
//! manager's lo→hi promotion pass uses to spot importance that emerged
//! after a slot was demoted. Only [`H2oPolicy`] implements it; the default
//! returns 0, making promotion a no-op under signal-free policies.

use crate::util::rng::Pcg32;

/// EMA weight of one decode step's attention row in the re-access signal
/// (see [`ImportancePolicy::reaccess`]): each step,
/// `ema ← (1 − α)·ema + α·attn`. Chosen so a slot's signal reacts within a
/// few steps yet one spiky row cannot flip a tier decision by itself.
pub const REACCESS_ALPHA: f32 = 0.25;

/// An online importance policy over `planes` independent (layer × kv-head)
/// planes, each with up to `max_slots` token slots.
pub trait ImportancePolicy: Send {
    fn name(&self) -> &'static str;

    /// Seed per-slot importance from the prefill pass. `acc[s]` is the
    /// attention mass slot `s` accumulated over all prefill queries
    /// (ignored by policies that don't use attention history).
    fn init_prefill(&mut self, plane: usize, acc: &[f32]);

    /// Observe one decode step's attention row for a plane. `attn[s]` is the
    /// probability the new query put on slot `s`.
    fn observe(&mut self, plane: usize, attn: &[f32]);

    /// Point update: add `mass` attention to a single slot. Equivalent to
    /// [`Self::observe`] with a one-hot row, without materializing it —
    /// this is how the decode hot path credits the new token's
    /// self-attention.
    fn observe_at(&mut self, plane: usize, slot: usize, mass: f32);

    /// Register that a new token occupies slot `s` (called on every decode
    /// step after `observe`).
    fn admit(&mut self, plane: usize, slot: usize);

    /// Observe the raw K/V rows of a newly admitted slot (prefill and
    /// decode). This is the attention-free signal channel: engines that
    /// never surface attention rows still call this, so KV-statistics
    /// policies ([`LagKvPolicy`]) rank tokens without any attention
    /// plumbing. Attention-based policies ignore it — the default no-op.
    fn observe_kv(&mut self, _plane: usize, _slot: usize, _k: &[f32], _v: &[f32]) {}

    /// Current importance score of a slot (higher = keep in hi tier).
    fn score(&self, plane: usize, slot: usize) -> f32;

    /// Post-demotion re-access signal: an EMA of the attention a slot
    /// received over *recent* decode steps (decayed by every `observe`),
    /// as opposed to [`Self::score`]'s lifetime accumulation. The cache
    /// manager's promotion pass compares lo-tier and hi-tier slots on this
    /// signal, so late-emerging importance (low score at demote time, high
    /// attention afterwards) is visible even when the cumulative score is
    /// still small. Policies without a recency-aware signal return 0,
    /// which makes promotion a no-op under them.
    fn reaccess(&self, _plane: usize, _slot: usize) -> f32 {
        0.0
    }

    /// Pick the demotion victim among `candidates` (non-empty, all currently
    /// hi-tier, recency-protected slots already excluded). Default: argmin
    /// of `score`.
    fn select_victim(&mut self, plane: usize, candidates: &[usize]) -> usize {
        let mut best = candidates[0];
        let mut best_score = self.score(plane, best);
        for &c in &candidates[1..] {
            let s = self.score(plane, c);
            if s < best_score {
                best = c;
                best_score = s;
            }
        }
        best
    }

    /// Serialize the policy's mutable state into `out` (appended; format is
    /// policy-private, round-tripped only through [`Self::import_state`]).
    /// Stateless policies append nothing — the default.
    fn export_state(&self, _out: &mut Vec<u8>) {}

    /// Restore state captured by [`Self::export_state`]. Returns `false` if
    /// the bytes are malformed (wrong length, wrong shape) — the caller
    /// treats that as a corrupt snapshot, so implementations must validate
    /// rather than panic. The stateless default accepts only an empty blob.
    fn import_state(&mut self, bytes: &[u8]) -> bool {
        bytes.is_empty()
    }
}

// ---- state-blob helpers (shared by the stateful policies) ----------------

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32_vec(out: &mut Vec<u8>, xs: &[f32]) {
    put_u64(out, xs.len() as u64);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn take_u64(bytes: &[u8], pos: &mut usize) -> Option<u64> {
    let raw = bytes.get(*pos..*pos + 8)?;
    *pos += 8;
    Some(u64::from_le_bytes(raw.try_into().ok()?))
}

fn take_f32_vec(bytes: &[u8], pos: &mut usize) -> Option<Vec<f32>> {
    let n = take_u64(bytes, pos)? as usize;
    // cap: a plane vector can never exceed the remaining payload
    if n > (bytes.len() - *pos) / 4 {
        return None;
    }
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        let raw = bytes.get(*pos..*pos + 4)?;
        *pos += 4;
        v.push(f32::from_le_bytes(raw.try_into().ok()?));
    }
    Some(v)
}

fn take_plane_vecs(bytes: &[u8], pos: &mut usize, planes: usize) -> Option<Vec<Vec<f32>>> {
    let n = take_u64(bytes, pos)? as usize;
    if n != planes {
        return None;
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(take_f32_vec(bytes, pos)?);
    }
    Some(out)
}

fn put_plane_vecs(out: &mut Vec<u8>, planes: &[Vec<f32>]) {
    put_u64(out, planes.len() as u64);
    for p in planes {
        put_f32_vec(out, p);
    }
}

/// Accumulated-attention heavy-hitter policy (H2O).
///
/// Slot vectors grow lazily with the observed sequence length, so a policy
/// for a `max_seq = 4096` model costs only its occupancy (matching the
/// pooled cache-manager shadow blocks).
pub struct H2oPolicy {
    /// `[plane][slot]` accumulated attention mass (grown on demand).
    acc: Vec<Vec<f32>>,
    /// `[plane][slot]` re-access EMA over recent decode steps (grown on
    /// demand alongside `acc`; decayed by every `observe`). Powers
    /// [`ImportancePolicy::reaccess`] for the promotion pass.
    ema: Vec<Vec<f32>>,
}

impl H2oPolicy {
    pub fn new(planes: usize, _max_slots: usize) -> Self {
        Self {
            acc: vec![Vec::new(); planes],
            ema: vec![Vec::new(); planes],
        }
    }
}

impl ImportancePolicy for H2oPolicy {
    fn name(&self) -> &'static str {
        "h2o"
    }

    fn init_prefill(&mut self, plane: usize, acc: &[f32]) {
        let mine = &mut self.acc[plane];
        if mine.len() < acc.len() {
            mine.resize(acc.len(), 0.0);
        }
        mine[..acc.len()].copy_from_slice(acc);
        // The re-access EMA is a *post-prefill* signal: it starts at zero
        // and only decode-step observations move it, so promotion pressure
        // reflects what happened after tier placement, not the prefill.
        let ema = &mut self.ema[plane];
        if ema.len() < acc.len() {
            ema.resize(acc.len(), 0.0);
        }
        ema[..acc.len()].fill(0.0);
    }

    fn observe(&mut self, plane: usize, attn: &[f32]) {
        let mine = &mut self.acc[plane];
        if mine.len() < attn.len() {
            mine.resize(attn.len(), 0.0);
        }
        for (a, &p) in mine.iter_mut().zip(attn) {
            *a += p;
        }
        let ema = &mut self.ema[plane];
        if ema.len() < attn.len() {
            ema.resize(attn.len(), 0.0);
        }
        for (e, &p) in ema.iter_mut().zip(attn) {
            *e = (1.0 - REACCESS_ALPHA) * *e + REACCESS_ALPHA * p;
        }
    }

    fn observe_at(&mut self, plane: usize, slot: usize, mass: f32) {
        let mine = &mut self.acc[plane];
        if mine.len() <= slot {
            mine.resize(slot + 1, 0.0);
        }
        mine[slot] += mass;
        let ema = &mut self.ema[plane];
        if ema.len() <= slot {
            ema.resize(slot + 1, 0.0);
        }
        ema[slot] = (1.0 - REACCESS_ALPHA) * ema[slot] + REACCESS_ALPHA * mass;
    }

    fn admit(&mut self, _plane: usize, _slot: usize) {}

    fn score(&self, plane: usize, slot: usize) -> f32 {
        self.acc[plane].get(slot).copied().unwrap_or(0.0)
    }

    fn reaccess(&self, plane: usize, slot: usize) -> f32 {
        self.ema[plane].get(slot).copied().unwrap_or(0.0)
    }

    fn export_state(&self, out: &mut Vec<u8>) {
        put_plane_vecs(out, &self.acc);
        put_plane_vecs(out, &self.ema);
    }

    fn import_state(&mut self, bytes: &[u8]) -> bool {
        let mut pos = 0usize;
        let Some(acc) = take_plane_vecs(bytes, &mut pos, self.acc.len()) else {
            return false;
        };
        let Some(ema) = take_plane_vecs(bytes, &mut pos, self.ema.len()) else {
            return false;
        };
        if pos != bytes.len() {
            return false;
        }
        self.acc = acc;
        self.ema = ema;
        true
    }
}

/// Recency policy: importance = slot index (newest wins).
pub struct LocalPolicy;

impl ImportancePolicy for LocalPolicy {
    fn name(&self) -> &'static str {
        "local"
    }

    fn init_prefill(&mut self, _plane: usize, _acc: &[f32]) {}
    fn observe(&mut self, _plane: usize, _attn: &[f32]) {}
    fn observe_at(&mut self, _plane: usize, _slot: usize, _mass: f32) {}
    fn admit(&mut self, _plane: usize, _slot: usize) {}

    fn score(&self, _plane: usize, slot: usize) -> f32 {
        slot as f32
    }
}

/// Random importance — the control showing that *which* tokens are kept hi
/// matters (paper's argument that importance criteria help, Fig. 6 vs RTN).
pub struct RandomPolicy {
    rng: Pcg32,
    /// `[plane][slot]` scores drawn lazily on admit (grown on demand).
    scores: Vec<Vec<f32>>,
}

impl RandomPolicy {
    pub fn new(planes: usize, _max_slots: usize, seed: u64) -> Self {
        Self {
            rng: Pcg32::new(seed),
            scores: vec![Vec::new(); planes],
        }
    }

    fn ensure(&mut self, plane: usize, slots: usize) {
        let mine = &mut self.scores[plane];
        if mine.len() < slots {
            mine.resize(slots, 0.0);
        }
    }
}

impl ImportancePolicy for RandomPolicy {
    fn name(&self) -> &'static str {
        "random"
    }

    fn init_prefill(&mut self, plane: usize, acc: &[f32]) {
        self.ensure(plane, acc.len());
        for s in 0..acc.len() {
            self.scores[plane][s] = self.rng.gen_f32();
        }
    }

    fn observe(&mut self, _plane: usize, _attn: &[f32]) {}

    fn observe_at(&mut self, _plane: usize, _slot: usize, _mass: f32) {}

    fn admit(&mut self, plane: usize, slot: usize) {
        self.ensure(plane, slot + 1);
        self.scores[plane][slot] = self.rng.gen_f32();
    }

    fn score(&self, plane: usize, slot: usize) -> f32 {
        self.scores[plane].get(slot).copied().unwrap_or(0.0)
    }

    fn export_state(&self, out: &mut Vec<u8>) {
        let (state, inc) = self.rng.state_parts();
        put_u64(out, state);
        put_u64(out, inc);
        put_plane_vecs(out, &self.scores);
    }

    fn import_state(&mut self, bytes: &[u8]) -> bool {
        let mut pos = 0usize;
        let (Some(state), Some(inc)) = (take_u64(bytes, &mut pos), take_u64(bytes, &mut pos))
        else {
            return false;
        };
        let Some(scores) = take_plane_vecs(bytes, &mut pos, self.scores.len()) else {
            return false;
        };
        if pos != bytes.len() {
            return false;
        }
        self.rng = Pcg32::from_parts(state, inc);
        self.scores = scores;
        true
    }
}

/// Trailing-window length of [`LagKvPolicy`]: a new slot's K/V rows are
/// normalized against the statistics of the previous `LAG_WINDOW` rows.
/// Matches the partition size regime of the LagKV paper (small relative to
/// typical sequence lengths, large enough for stable per-channel min/max).
pub const LAG_WINDOW: usize = 16;

/// Lag-relative KV-statistics importance (LagKV, PAPERS.md).
///
/// The paper scores each token by min-max normalizing its K and V rows
/// against a *lag* partition of neighboring tokens and taking the standard
/// deviation across channels: tokens whose rows deviate from the local
/// typical range are informative, tokens inside it are redundant. The paper
/// uses the *next* partition as the reference; an online policy cannot see
/// the future, so this implementation uses the trailing `LAG_WINDOW` rows —
/// the same lag-relative signal, causal.
///
/// Crucially the signal is derived from the KV rows alone
/// ([`ImportancePolicy::observe_kv`]); `init_prefill`/`observe`/`observe_at`
/// are no-ops, so the ranking is identical whether or not the engine
/// surfaces attention.
pub struct LagKvPolicy {
    /// `[plane][slot]` frozen score, computed once at `observe_kv` time.
    scores: Vec<Vec<f32>>,
    /// `[plane]` ring of the last `LAG_WINDOW` K rows (`[LAG_WINDOW × d]`,
    /// grown lazily once the head dim is known).
    k_ring: Vec<Vec<f32>>,
    v_ring: Vec<Vec<f32>>,
    /// `[plane]` total rows observed (ring fill = min(seen, LAG_WINDOW)).
    seen: Vec<u64>,
    /// Head dim, discovered at the first `observe_kv`.
    dim: usize,
    /// Reusable `[d]` channel min/max scratch (transient, not serialized).
    mins: Vec<f32>,
    maxs: Vec<f32>,
}

impl LagKvPolicy {
    pub fn new(planes: usize, _max_slots: usize) -> Self {
        Self {
            scores: vec![Vec::new(); planes],
            k_ring: vec![Vec::new(); planes],
            v_ring: vec![Vec::new(); planes],
            seen: vec![0; planes],
            dim: 0,
            mins: Vec::new(),
            maxs: Vec::new(),
        }
    }

    /// Channel-wise min-max over the filled part of a ring (`rows` rows of
    /// width `d`), written into `mins`/`maxs`.
    fn ring_min_max(ring: &[f32], rows: usize, d: usize, mins: &mut [f32], maxs: &mut [f32]) {
        mins.fill(f32::INFINITY);
        maxs.fill(f32::NEG_INFINITY);
        for r in 0..rows {
            for c in 0..d {
                let x = ring[r * d + c];
                if x < mins[c] {
                    mins[c] = x;
                }
                if x > maxs[c] {
                    maxs[c] = x;
                }
            }
        }
    }

    /// Std over channels of the min-max-normalized row — the LagKV spread
    /// statistic. `mins`/`maxs` come from the reference window.
    fn normalized_std(row: &[f32], mins: &[f32], maxs: &[f32]) -> f32 {
        let d = row.len();
        if d == 0 {
            return 0.0;
        }
        let mut sum = 0.0f32;
        let mut sum2 = 0.0f32;
        for c in 0..d {
            let span = maxs[c] - mins[c];
            let z = if span > 1e-12 {
                (row[c] - mins[c]) / span
            } else {
                0.0
            };
            sum += z;
            sum2 += z * z;
        }
        let mean = sum / d as f32;
        (sum2 / d as f32 - mean * mean).max(0.0).sqrt()
    }
}

impl ImportancePolicy for LagKvPolicy {
    fn name(&self) -> &'static str {
        "lagkv"
    }

    // Attention inputs are deliberately ignored: the LagKV contract is that
    // the ranking is a pure function of the observed KV rows.
    fn init_prefill(&mut self, _plane: usize, _acc: &[f32]) {}
    fn observe(&mut self, _plane: usize, _attn: &[f32]) {}
    fn observe_at(&mut self, _plane: usize, _slot: usize, _mass: f32) {}
    fn admit(&mut self, _plane: usize, _slot: usize) {}

    fn observe_kv(&mut self, plane: usize, slot: usize, k: &[f32], v: &[f32]) {
        if self.dim == 0 {
            self.dim = k.len();
        }
        let d = self.dim;
        debug_assert!(k.len() == d && v.len() == d);
        if self.k_ring[plane].is_empty() {
            self.k_ring[plane].resize(LAG_WINDOW * d, 0.0);
            self.v_ring[plane].resize(LAG_WINDOW * d, 0.0);
        }
        let filled = (self.seen[plane] as usize).min(LAG_WINDOW);
        let score = if filled == 0 {
            // No reference window yet (the very first row of the plane):
            // nothing to deviate from.
            0.0
        } else {
            if self.mins.len() < d {
                self.mins.resize(d, 0.0);
                self.maxs.resize(d, 0.0);
            }
            Self::ring_min_max(&self.k_ring[plane], filled, d, &mut self.mins, &mut self.maxs);
            let sk = Self::normalized_std(&k[..d], &self.mins[..d], &self.maxs[..d]);
            Self::ring_min_max(&self.v_ring[plane], filled, d, &mut self.mins, &mut self.maxs);
            let sv = Self::normalized_std(&v[..d], &self.mins[..d], &self.maxs[..d]);
            sk + sv
        };
        let mine = &mut self.scores[plane];
        if mine.len() <= slot {
            mine.resize(slot + 1, 0.0);
        }
        mine[slot] = score;
        // Rotate the row into the window.
        let pos = (self.seen[plane] as usize % LAG_WINDOW) * d;
        self.k_ring[plane][pos..pos + d].copy_from_slice(&k[..d]);
        self.v_ring[plane][pos..pos + d].copy_from_slice(&v[..d]);
        self.seen[plane] += 1;
    }

    fn score(&self, plane: usize, slot: usize) -> f32 {
        self.scores[plane].get(slot).copied().unwrap_or(0.0)
    }

    fn export_state(&self, out: &mut Vec<u8>) {
        put_u64(out, self.dim as u64);
        put_u64(out, self.seen.len() as u64);
        for &s in &self.seen {
            put_u64(out, s);
        }
        put_plane_vecs(out, &self.scores);
        put_plane_vecs(out, &self.k_ring);
        put_plane_vecs(out, &self.v_ring);
    }

    fn import_state(&mut self, bytes: &[u8]) -> bool {
        let planes = self.scores.len();
        let mut pos = 0usize;
        let Some(dim) = take_u64(bytes, &mut pos) else {
            return false;
        };
        let Some(n_seen) = take_u64(bytes, &mut pos) else {
            return false;
        };
        if n_seen as usize != planes {
            return false;
        }
        let mut seen = Vec::with_capacity(planes);
        for _ in 0..planes {
            match take_u64(bytes, &mut pos) {
                Some(s) => seen.push(s),
                None => return false,
            }
        }
        let Some(scores) = take_plane_vecs(bytes, &mut pos, planes) else {
            return false;
        };
        let Some(k_ring) = take_plane_vecs(bytes, &mut pos, planes) else {
            return false;
        };
        let Some(v_ring) = take_plane_vecs(bytes, &mut pos, planes) else {
            return false;
        };
        if pos != bytes.len() {
            return false;
        }
        let d = dim as usize;
        for (kr, vr) in k_ring.iter().zip(&v_ring) {
            let want = if kr.is_empty() { 0 } else { LAG_WINDOW * d };
            if kr.len() != want || vr.len() != want {
                return false;
            }
        }
        self.dim = d;
        self.seen = seen;
        self.scores = scores;
        self.k_ring = k_ring;
        self.v_ring = v_ring;
        true
    }
}

/// Policy factory by name.
pub fn make_policy(
    name: &str,
    planes: usize,
    max_slots: usize,
    seed: u64,
) -> Option<Box<dyn ImportancePolicy>> {
    Some(match name {
        "h2o" => Box::new(H2oPolicy::new(planes, max_slots)),
        "local" => Box::new(LocalPolicy),
        "random" => Box::new(RandomPolicy::new(planes, max_slots, seed)),
        "lagkv" => Box::new(LagKvPolicy::new(planes, max_slots)),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h2o_accumulates_and_selects_min() {
        let mut p = H2oPolicy::new(2, 4);
        p.init_prefill(0, &[0.5, 0.1, 0.3, 0.1]);
        p.observe(0, &[0.1, 0.0, 0.8, 0.1]);
        assert!((p.score(0, 0) - 0.6).abs() < 1e-6);
        assert!((p.score(0, 2) - 1.1).abs() < 1e-6);
        // victim among {0,1,2} is slot 1 (0.1)
        assert_eq!(p.select_victim(0, &[0, 1, 2]), 1);
        // planes are independent
        assert_eq!(p.score(1, 0), 0.0);
    }

    #[test]
    fn h2o_prefill_seeding_drives_early_victims() {
        let mut p = H2oPolicy::new(1, 8);
        p.init_prefill(0, &[0.9, 0.01, 0.5, 0.02, 0.3, 0.02, 0.02, 0.2]);
        let candidates: Vec<usize> = (0..8).collect();
        assert_eq!(p.select_victim(0, &candidates), 1);
    }

    #[test]
    fn local_prefers_recent() {
        let mut p = LocalPolicy;
        assert_eq!(p.select_victim(0, &[3, 7, 1, 5]), 1);
        assert!(p.score(0, 10) > p.score(0, 2));
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let mk = |seed| {
            let mut p = RandomPolicy::new(1, 16, seed);
            p.init_prefill(0, &vec![0.0; 16]);
            (0..16).map(|s| p.score(0, s)).collect::<Vec<_>>()
        };
        assert_eq!(mk(5), mk(5));
        assert_ne!(mk(5), mk(6));
    }

    #[test]
    fn factory_resolves_names() {
        for name in ["h2o", "local", "random", "lagkv"] {
            let p = make_policy(name, 2, 8, 1).unwrap();
            assert_eq!(p.name(), name);
        }
        assert!(make_policy("oracle", 1, 1, 0).is_none()); // lives in the graph
    }

    /// Deterministic K/V row for LagKV tests: filler rows live in a narrow
    /// band, the "needle" row is far outside it.
    fn lag_row(i: usize, needle: bool, d: usize) -> Vec<f32> {
        (0..d)
            .map(|c| {
                if needle {
                    if c % 2 == 0 {
                        4.0
                    } else {
                        -4.0
                    }
                } else {
                    0.1 * ((i * 7 + c * 3) % 5) as f32
                }
            })
            .collect()
    }

    #[test]
    fn lagkv_scores_distinct_row_above_filler() {
        let d = 16;
        let mut p = LagKvPolicy::new(1, 64);
        for s in 0..24 {
            let needle = s == 20;
            let row = lag_row(s, needle, d);
            p.observe_kv(0, s, &row, &row);
        }
        let needle_score = p.score(0, 20);
        // every scored filler slot past the warmup window ranks below it
        for s in LAG_WINDOW..24 {
            if s == 20 {
                continue;
            }
            assert!(
                p.score(0, s) < needle_score,
                "filler slot {s} ({}) >= needle ({needle_score})",
                p.score(0, s)
            );
        }
    }

    /// The LagKV contract from the paper: the ranking is a pure function of
    /// the KV rows. Feeding one policy a full attention stream
    /// (prefill seed + dense rows + point updates) while the other gets
    /// none must produce bit-identical scores — the StubEngine/no-attention
    /// path ranks exactly like the attention-surfacing path.
    #[test]
    fn lagkv_ranking_is_attention_free() {
        let d = 8;
        let mut with_attn = LagKvPolicy::new(2, 32);
        let mut without = LagKvPolicy::new(2, 32);
        with_attn.init_prefill(0, &[0.5; 16]);
        for s in 0..24 {
            let k = lag_row(s, s % 9 == 0, d);
            let v = lag_row(s + 1, s % 7 == 0, d);
            with_attn.observe_kv(0, s, &k, &v);
            without.observe_kv(0, s, &k, &v);
            // attention stream goes only to one of them
            with_attn.observe(0, &vec![1.0 / (s + 1) as f32; s + 1]);
            with_attn.observe_at(0, s, 0.9);
            with_attn.admit(0, s);
        }
        for s in 0..24 {
            assert_eq!(
                with_attn.score(0, s).to_bits(),
                without.score(0, s).to_bits(),
                "slot {s}"
            );
        }
        // and the victim choice (the decision that matters) agrees
        let candidates: Vec<usize> = (0..24).collect();
        assert_eq!(
            with_attn.select_victim(0, &candidates),
            without.select_victim(0, &candidates)
        );
    }

    #[test]
    fn lagkv_state_round_trip_is_exact() {
        let d = 8;
        let mut src = LagKvPolicy::new(2, 32);
        for s in 0..20 {
            let k = lag_row(s, s == 10, d);
            src.observe_kv(0, s, &k, &k);
        }
        src.observe_kv(1, 0, &lag_row(0, false, d), &lag_row(1, false, d));
        let mut blob = Vec::new();
        src.export_state(&mut blob);

        let mut dst = LagKvPolicy::new(2, 32);
        assert!(dst.import_state(&blob));
        for s in 0..20 {
            assert_eq!(src.score(0, s).to_bits(), dst.score(0, s).to_bits());
        }
        // the ring resumed too: the next observation scores identically
        let next = lag_row(21, false, d);
        src.observe_kv(0, 20, &next, &next);
        dst.observe_kv(0, 20, &next, &next);
        assert_eq!(src.score(0, 20).to_bits(), dst.score(0, 20).to_bits());

        // malformed blobs are rejected
        let mut q = LagKvPolicy::new(2, 32);
        assert!(!q.import_state(&blob[..blob.len() - 1]));
        let mut wrong_planes = LagKvPolicy::new(3, 32);
        assert!(!wrong_planes.import_state(&blob));
    }

    #[test]
    fn lagkv_default_signals_are_inert() {
        // reaccess stays 0 (promotion is a no-op under LagKV) and scores of
        // never-observed slots are 0, not a panic.
        let p = LagKvPolicy::new(1, 4096);
        assert_eq!(p.reaccess(0, 3), 0.0);
        assert_eq!(p.score(0, 4000), 0.0);
    }

    #[test]
    fn default_victim_breaks_ties_by_first() {
        let mut p = H2oPolicy::new(1, 4); // all scores zero
        assert_eq!(p.select_victim(0, &[2, 1, 3]), 2);
    }

    #[test]
    fn observe_at_equals_one_hot_observe() {
        let mut point = H2oPolicy::new(1, 8);
        let mut dense = H2oPolicy::new(1, 8);
        point.init_prefill(0, &[0.1, 0.2, 0.3]);
        dense.init_prefill(0, &[0.1, 0.2, 0.3]);
        // credit slot 3 (one beyond the prefill) with mass 0.7
        point.observe_at(0, 3, 0.7);
        dense.observe(0, &[0.0, 0.0, 0.0, 0.7]);
        for s in 0..5 {
            assert!(
                (point.score(0, s) - dense.score(0, s)).abs() < 1e-9,
                "slot {s}"
            );
        }
    }

    /// The re-access EMA is recency-weighted where the score is lifetime:
    /// a slot hammered early then ignored ends with a high score but a
    /// decayed EMA, while a late bloomer (the promotion motivation) ends
    /// with a small score but the dominant EMA.
    #[test]
    fn h2o_reaccess_tracks_recent_attention_not_lifetime() {
        let mut p = H2oPolicy::new(1, 8);
        p.init_prefill(0, &[0.9, 0.1, 0.1, 0.1]);
        assert_eq!(p.reaccess(0, 0), 0.0, "EMA starts at zero after prefill");

        // 8 steps of attention on slot 0 only, then 8 steps on slot 3 only.
        for _ in 0..8 {
            p.observe(0, &[0.8, 0.0, 0.0, 0.0]);
        }
        for _ in 0..8 {
            p.observe(0, &[0.0, 0.0, 0.0, 0.8]);
        }
        assert!(
            p.score(0, 0) > p.score(0, 3),
            "lifetime score still favours the early slot: {} vs {}",
            p.score(0, 0),
            p.score(0, 3)
        );
        assert!(
            p.reaccess(0, 3) > 4.0 * p.reaccess(0, 0),
            "re-access EMA favours the late bloomer: {} vs {}",
            p.reaccess(0, 3),
            p.reaccess(0, 0)
        );
        // EMA is bounded by the observed mass (it is an average, not a sum).
        assert!(p.reaccess(0, 3) <= 0.8 + 1e-6);
    }

    /// Policies without a recency signal report 0, making promotion a
    /// no-op under them by construction.
    #[test]
    fn reaccess_defaults_to_zero_for_non_recency_policies() {
        let mut local = LocalPolicy;
        local.observe(0, &[0.5, 0.5]);
        assert_eq!(local.reaccess(0, 1), 0.0);
        let mut random = RandomPolicy::new(1, 8, 3);
        random.init_prefill(0, &[0.0; 4]);
        random.observe(0, &[0.5; 4]);
        assert_eq!(random.reaccess(0, 2), 0.0);
    }

    #[test]
    fn h2o_state_round_trip_is_exact() {
        let mut src = H2oPolicy::new(2, 16);
        src.init_prefill(0, &[0.5, 0.1, 0.3]);
        src.observe(0, &[0.1, 0.0, 0.8, 0.1]);
        src.observe_at(1, 5, 0.7);
        let mut blob = Vec::new();
        src.export_state(&mut blob);

        let mut dst = H2oPolicy::new(2, 16);
        assert!(dst.import_state(&blob));
        for plane in 0..2 {
            for slot in 0..8 {
                assert_eq!(src.score(plane, slot), dst.score(plane, slot));
                assert_eq!(src.reaccess(plane, slot), dst.reaccess(plane, slot));
            }
        }
        // further identical observations keep them in lockstep
        src.observe(0, &[0.2, 0.2, 0.2, 0.2, 0.2]);
        dst.observe(0, &[0.2, 0.2, 0.2, 0.2, 0.2]);
        assert_eq!(src.score(0, 4), dst.score(0, 4));
    }

    #[test]
    fn random_state_round_trip_resumes_rng_stream() {
        let mut src = RandomPolicy::new(1, 16, 77);
        src.init_prefill(0, &[0.0; 8]);
        let mut blob = Vec::new();
        src.export_state(&mut blob);

        // a fresh policy with a different seed converges after import
        let mut dst = RandomPolicy::new(1, 16, 999);
        assert!(dst.import_state(&blob));
        for s in 0..8 {
            assert_eq!(src.score(0, s), dst.score(0, s));
        }
        // the RNG stream continues identically: next admits draw equal scores
        src.admit(0, 8);
        dst.admit(0, 8);
        assert_eq!(src.score(0, 8), dst.score(0, 8));
    }

    #[test]
    fn import_rejects_malformed_blobs() {
        let mut src = H2oPolicy::new(2, 8);
        src.init_prefill(0, &[0.5, 0.1]);
        let mut blob = Vec::new();
        src.export_state(&mut blob);

        // truncated
        let mut p = H2oPolicy::new(2, 8);
        assert!(!p.import_state(&blob[..blob.len() - 1]));
        // trailing garbage
        let mut extended = blob.clone();
        extended.push(0xAB);
        assert!(!p.import_state(&extended));
        // wrong plane count
        let mut q = H2oPolicy::new(3, 8);
        assert!(!q.import_state(&blob));
        // stateless default accepts only empty
        let mut local = LocalPolicy;
        assert!(local.import_state(&[]));
        assert!(!local.import_state(&[1, 2, 3]));
    }

    #[test]
    fn policies_grow_lazily_beyond_seen_slots() {
        // scores of never-observed slots are 0, not a panic — policies no
        // longer preallocate max_seq-sized vectors.
        let p = H2oPolicy::new(2, 4096);
        assert_eq!(p.score(1, 4000), 0.0);
        let r = RandomPolicy::new(1, 4096, 3);
        assert_eq!(r.score(0, 4000), 0.0);
    }
}
