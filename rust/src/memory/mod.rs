//! Analytic KV-cache memory footprint calculator — reproduces paper Table 5.
//!
//! Table 5 is pure arithmetic over published model architectures: the KV
//! cache of a decoder-only transformer holds, per token per layer,
//! `2 × n_kv_heads × head_dim` values. At FP16 that is
//! `4 × n_kv_heads × head_dim` bytes; MiKV's compressed cache is scaled by
//! the logical cache-size fraction. This module carries the real Llama-2 /
//! Mistral architectures so the numbers match the paper *exactly*.

use crate::kvcache::{accounting::bits_per_token, TierConfig};
use crate::quant::Precision;

/// Decoder-only transformer architecture (the KV-relevant fields).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: &'static str,
    pub layers: usize,
    pub q_heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
}

impl ModelSpec {
    pub fn gqa(&self) -> bool {
        self.kv_heads < self.q_heads
    }
}

/// The four backbones of paper Table 5.
pub fn paper_models() -> Vec<ModelSpec> {
    vec![
        ModelSpec {
            name: "Llama-2-7b",
            layers: 32,
            q_heads: 32,
            kv_heads: 32,
            head_dim: 128,
        },
        ModelSpec {
            name: "Mistral-7b",
            layers: 32,
            q_heads: 32,
            kv_heads: 8, // GQA
            head_dim: 128,
        },
        ModelSpec {
            name: "Llama-2-13b",
            layers: 40,
            q_heads: 40,
            kv_heads: 40,
            head_dim: 128,
        },
        ModelSpec {
            name: "Llama-2-70b",
            layers: 80,
            q_heads: 64,
            kv_heads: 8, // GQA
            head_dim: 128,
        },
    ]
}

/// Full (FP16, uncompressed) KV cache size in bytes.
pub fn full_cache_bytes(m: &ModelSpec, batch: usize, seq: usize) -> u64 {
    // 2 (K+V) × 2 bytes (FP16) per value.
    (batch * seq * m.layers * m.kv_heads * m.head_dim) as u64 * 2 * 2
}

/// The cache sizes *as claimed in paper Table 5* for batch 8, seq 4096.
///
/// Reverse-engineering the published figures shows they correspond to
/// **4 bytes per value (FP32)** rather than the FP16 the text describes
/// (Llama-2-7b: 34.36GB = 8·4096·32·4096·2·4 bytes; FP16 gives 17.18GB),
/// and the Llama-2-70b row (17.18GB) additionally matches only with 64
/// layers instead of the model's 80 (64 is its *head* count). We reproduce
/// the claimed numbers exactly here so the Table 5 bench can print
/// paper-vs-ours side by side; `full_cache_bytes` above is the
/// architecture-correct FP16 calculation.
pub fn paper_table5_claimed_bytes(m: &ModelSpec, batch: usize, seq: usize) -> u64 {
    let layers = if m.name == "Llama-2-70b" { 64 } else { m.layers };
    (batch * seq * layers * m.kv_heads * m.head_dim) as u64 * 2 * 4
}

/// Cache size under MiKV with the given tiers and hi fraction — exact
/// logical bytes including quantization metadata.
pub fn mikv_cache_bytes(
    m: &ModelSpec,
    batch: usize,
    seq: usize,
    hi: &TierConfig,
    lo: &TierConfig,
    hi_fraction: f64,
) -> u64 {
    let slots = (batch * seq * m.layers * m.kv_heads) as f64;
    let hi_bits = bits_per_token(hi, m.head_dim) as f64;
    let lo_bits = bits_per_token(lo, m.head_dim) as f64;
    let total_bits = slots * (hi_fraction * hi_bits + (1.0 - hi_fraction) * lo_bits);
    (total_bits / 8.0).round() as u64
}

/// Cache size at a *target* compressed percentage of full (the way the
/// paper reports Table 5: "Cache Size 25%" rows are exactly full × 0.25).
pub fn cache_bytes_at_pct(m: &ModelSpec, batch: usize, seq: usize, pct: f64) -> u64 {
    (full_cache_bytes(m, batch, seq) as f64 * pct / 100.0).round() as u64
}

/// Format bytes as the paper does (GB with two decimals, GB = 10^9 per the
/// paper's 34.36GB figure for Llama-2-7b @ b=8, s=4096).
pub fn fmt_gb(bytes: u64) -> String {
    format!("{:.2}GB", bytes as f64 / 1e9)
}

/// A configuration that achieves roughly a given cache % with MiKV tiers,
/// for the Table 5 "25%/20%" rows: returns (hi_fraction, lo precision).
pub fn tiers_for_target_pct(pct: f64, head_dim: usize) -> (f64, TierConfig, TierConfig) {
    let hi = TierConfig::fp16();
    let lo = TierConfig::quantized(Precision::Int2, head_dim / 2);
    // solve hi_f·16 + (1−hi_f)·lo_bits_effective = pct·16 / 100 … but we just
    // search the hi fraction numerically for exactness.
    let lo_frac = bits_per_token(&lo, head_dim) as f64 / bits_per_token(&hi, head_dim) as f64;
    let hi_f = ((pct / 100.0) - lo_frac) / (1.0 - lo_frac);
    (hi_f.clamp(0.0, 1.0), hi, lo)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 5, "100%" rows @ batch 8, seq 4096 — claimed figures.
    #[test]
    fn full_cache_matches_paper_table5_claims() {
        let cases = [
            ("Llama-2-7b", 34.36),
            ("Mistral-7b", 8.59),
            ("Llama-2-13b", 53.69),
            ("Llama-2-70b", 17.18),
        ];
        for m in paper_models() {
            let expect = cases.iter().find(|(n, _)| *n == m.name).unwrap().1;
            let got = paper_table5_claimed_bytes(&m, 8, 4096) as f64 / 1e9;
            assert!(
                (got - expect).abs() < 0.01,
                "{}: got {got:.2}GB, paper {expect}GB",
                m.name
            );
        }
    }

    /// Architecture-correct FP16 sizes (what the text describes): exactly
    /// half the claimed FP32-like figures, except the 70b layer-count slip.
    #[test]
    fn fp16_full_cache_is_half_the_claims() {
        for m in paper_models() {
            let fp16 = full_cache_bytes(&m, 8, 4096) as f64;
            let claimed = paper_table5_claimed_bytes(&m, 8, 4096) as f64;
            let expect_ratio = if m.name == "Llama-2-70b" {
                2.0 * 64.0 / 80.0
            } else {
                2.0
            };
            assert!(
                (claimed / fp16 - expect_ratio).abs() < 1e-9,
                "{}: ratio {}",
                m.name,
                claimed / fp16
            );
        }
    }

    /// Paper Table 5, 25% / 20% rows (fractions of the claimed 100% rows).
    #[test]
    fn compressed_rows_match_paper_table5() {
        let cases = [
            ("Llama-2-7b", 25.0, 8.59),
            ("Llama-2-7b", 20.0, 6.87),
            ("Mistral-7b", 25.0, 2.15),
            ("Mistral-7b", 20.0, 1.72),
            ("Llama-2-13b", 25.0, 13.42),
            ("Llama-2-13b", 20.0, 10.74),
            ("Llama-2-70b", 25.0, 4.30),
            ("Llama-2-70b", 20.0, 3.44),
        ];
        for (name, pct, expect) in cases {
            let m = paper_models().into_iter().find(|m| m.name == name).unwrap();
            let got =
                (paper_table5_claimed_bytes(&m, 8, 4096) as f64 * pct / 100.0) / 1e9;
            assert!(
                (got - expect).abs() < 0.01,
                "{name}@{pct}%: got {got:.2}GB, paper {expect}GB"
            );
        }
    }

    #[test]
    fn gqa_flag() {
        let models = paper_models();
        assert!(!models[0].gqa()); // Llama-2-7b
        assert!(models[1].gqa()); // Mistral
        assert!(models[3].gqa()); // 70b
    }

    #[test]
    fn mikv_bytes_close_to_target() {
        // hi=FP16@20% + INT2 lo should land in the low-30s percent range
        // (paper Table 1 reports 32% for importance 20% + INT2).
        let m = &paper_models()[0];
        let hi = TierConfig::fp16();
        let lo = TierConfig::quantized(Precision::Int2, 64);
        let bytes = mikv_cache_bytes(m, 8, 4096, &hi, &lo, 0.20);
        let pct = 100.0 * bytes as f64 / full_cache_bytes(m, 8, 4096) as f64;
        assert!((30.0..35.0).contains(&pct), "pct={pct:.1}");
    }

    #[test]
    fn tiers_for_target_solves_fraction() {
        let (hi_f, hi, lo) = tiers_for_target_pct(25.0, 128);
        let m = &paper_models()[0];
        let bytes = mikv_cache_bytes(m, 8, 4096, &hi, &lo, hi_f);
        let pct = 100.0 * bytes as f64 / full_cache_bytes(m, 8, 4096) as f64;
        assert!((pct - 25.0).abs() < 0.5, "pct={pct:.2}");
    }

    #[test]
    fn fmt_gb_matches_paper_style() {
        assert_eq!(fmt_gb(34_359_738_368), "34.36GB");
    }
}
