//! Per-channel key quantization — paper Appendix C.
//!
//! Instead of quantizing each token's channel vector (per-token), quantize
//! each *channel* across a window of tokens. Outlier channels then get their
//! own scale and are isolated rather than inflating every token group's
//! dynamic range. The paper evaluates this as a *simulated hypothetical*
//! scheme (quantize-as-is, group size 64 along the sequence) because real
//! deployment needs buffering and an altered eviction policy — we reproduce
//! exactly that simulation for Table 6, and the buffering machinery lives in
//! [`crate::kvcache`] as the `PerChannelSim` mode.

use super::f16::round_f16;
use super::Precision;

/// Per-channel quantization of a `[tokens, dim]` row-major block.
///
/// Each channel `c` is split into groups of `group` consecutive *tokens*;
/// scale/zero are computed per (channel, token-group). Returns the
/// dequantized block (the simulation never materializes packed storage).
pub fn quantize_dequantize_per_channel(
    block: &[f32],
    tokens: usize,
    dim: usize,
    precision: Precision,
    group: usize,
) -> Vec<f32> {
    assert_eq!(block.len(), tokens * dim);
    assert!(precision.is_quantized());
    let max_code = (precision.levels() - 1) as f32;
    let mut out = vec![0.0f32; block.len()];

    for c in 0..dim {
        let mut t0 = 0;
        while t0 < tokens {
            let t1 = (t0 + group).min(tokens);
            // min/max over tokens t0..t1 at channel c
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for t in t0..t1 {
                let v = block[t * dim + c];
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let alpha = round_f16((hi - lo) / max_code);
            let beta = round_f16(lo);
            for t in t0..t1 {
                let v = block[t * dim + c];
                let code = if alpha > 0.0 {
                    ((v - beta) / alpha).round().clamp(0.0, max_code)
                } else {
                    0.0
                };
                out[t * dim + c] = alpha * code + beta;
            }
            t0 = t1;
        }
    }
    out
}

/// Metadata overhead of the per-channel scheme, in bits per stored element
/// (scale+zero per (channel, token-group), FP16 each).
pub fn per_channel_overhead_bits(tokens: usize, group: usize) -> f64 {
    let groups_per_channel = (tokens + group - 1) / group;
    // per channel: groups * 2 * 16 bits, spread over `tokens` elements
    (groups_per_channel as f64 * 2.0 * 16.0) / tokens as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::quant::{dequantize, quantize, QuantParams};
    use crate::util::prop::{forall, gen_vec_normal, Config};
    use crate::util::rng::Pcg32;

    #[test]
    fn constant_channel_is_exact() {
        let tokens = 8;
        let dim = 4;
        let mut block = vec![0.0f32; tokens * dim];
        for t in 0..tokens {
            for c in 0..dim {
                block[t * dim + c] = c as f32; // constant per channel
            }
        }
        let out =
            quantize_dequantize_per_channel(&block, tokens, dim, Precision::Int2, 64);
        assert_eq!(out, block);
    }

    #[test]
    fn isolates_outlier_channels_better_than_per_token() {
        // Build a [tokens, dim] block with two systematic outlier channels —
        // per-channel INT2 must beat per-token INT2 on reconstruction error.
        let (tokens, dim) = (64usize, 32usize);
        let mut rng = Pcg32::new(123);
        let mut block = vec![0.0f32; tokens * dim];
        for t in 0..tokens {
            for c in 0..dim {
                let mut v = rng.gen_normal();
                if c == 5 || c == 21 {
                    v *= 30.0; // systematic outlier channel
                }
                block[t * dim + c] = v;
            }
        }
        let pc = quantize_dequantize_per_channel(&block, tokens, dim, Precision::Int2, 64);
        let err_pc: f64 = pc
            .iter()
            .zip(&block)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum();

        let prm = QuantParams::new(Precision::Int2, dim);
        let mut err_pt = 0.0f64;
        for t in 0..tokens {
            let row = &block[t * dim..(t + 1) * dim];
            let dq = dequantize(&quantize(row, prm));
            err_pt += dq
                .iter()
                .zip(row)
                .map(|(a, b)| (a - b).abs() as f64)
                .sum::<f64>();
        }
        assert!(
            err_pc < err_pt * 0.5,
            "per-channel {err_pc:.2} should beat per-token {err_pt:.2} by 2x under outliers"
        );
    }

    #[test]
    fn property_error_bounded_by_channel_range() {
        forall(Config::default().cases(100).name("per-channel bound"), |rng| {
            let tokens = rng.gen_range(1, 40) as usize;
            let dim = *rng.choose(&[4usize, 8]);
            let group = *rng.choose(&[8usize, 64]);
            let block = gen_vec_normal(rng, tokens * dim, 1.5, 0.05);
            let out = quantize_dequantize_per_channel(
                &block,
                tokens,
                dim,
                Precision::Int3,
                group,
            );
            for c in 0..dim {
                let mut t0 = 0;
                while t0 < tokens {
                    let t1 = (t0 + group).min(tokens);
                    let vals: Vec<f32> =
                        (t0..t1).map(|t| block[t * dim + c]).collect();
                    let range = vals.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v))
                        - vals.iter().fold(f32::INFINITY, |m, &v| m.min(v));
                    let step = range / 7.0; // int3 levels-1
                    let bound = 0.5 * step + (range + 10.0) / 1024.0 + 1e-5;
                    for t in t0..t1 {
                        let e = (out[t * dim + c] - block[t * dim + c]).abs();
                        prop_assert!(e <= bound, "err {e} > {bound}");
                    }
                    t0 = t1;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn overhead_bits_formula() {
        // 64 tokens, group 64: one group per channel → 32/64 bits/elem.
        assert!((per_channel_overhead_bits(64, 64) - 0.5).abs() < 1e-9);
        // 65 tokens → two groups per channel.
        assert!((per_channel_overhead_bits(65, 64) - 64.0 / 65.0).abs() < 1e-9);
    }
}
