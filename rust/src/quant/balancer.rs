//! Dynamic query/key outlier channel balancer — paper eq. (2)–(4).
//!
//! Systematic outliers appear in the *same channels* of the queries and keys
//! throughout a sequence (paper Fig. 5). Since queries stay in floating
//! point, quantization burden can be shifted from keys onto queries:
//!
//! ```text
//!   b_c = sqrt( max|q_c| / max|k_c| )          (2)  — from the prefill pass
//!   k̂_c = I(k_c · b_c)                          (3)  — quantize balanced key
//!   q̂_c = q_c / b_c                             (4)  — balance query to match
//! ```
//!
//! `q̂·k̂ = (q/b)·(k·b) = q·k`, so attention scores are preserved exactly in
//! infinite precision; in finite precision the balanced key has its outlier
//! channels shrunk toward the group's typical magnitude, which is what
//! rescues INT2 (paper Table 2).
//!
//! The runtime applies the *inverse* formulation: queries stay untouched and
//! the dequantized key is divided by `b` inside the fused attention kernel —
//! mathematically identical (see `python/compile/kernels/mikv_attn.py`) and
//! it keeps the high-precision tier's scores bit-identical to the
//! unbalanced path.

/// Per-channel balancer for one (layer, head).
#[derive(Debug, Clone, PartialEq)]
pub struct Balancer {
    /// `b` per channel, length = head dim.
    pub b: Vec<f32>,
}

/// Floor on per-channel maxima when forming the ratio; channels that never
/// activate would otherwise produce 0/0 or huge ratios.
const EPS: f32 = 1e-6;

impl Balancer {
    /// Identity balancer (outlier-awareness disabled).
    pub fn identity(dim: usize) -> Self {
        Self {
            b: vec![1.0; dim],
        }
    }

    /// Compute from per-channel absolute maxima of queries and keys observed
    /// during prefill (paper eq. 2).
    pub fn from_maxima(qmax: &[f32], kmax: &[f32]) -> Self {
        assert_eq!(qmax.len(), kmax.len());
        let b = qmax
            .iter()
            .zip(kmax)
            .map(|(&q, &k)| (q.max(EPS) / k.max(EPS)).sqrt())
            .collect();
        Self { b }
    }

    pub fn dim(&self) -> usize {
        self.b.len()
    }

    /// Balance a key vector before quantization (eq. 3): `k · b`.
    pub fn balance_key(&self, k: &[f32]) -> Vec<f32> {
        debug_assert_eq!(k.len(), self.b.len());
        k.iter().zip(&self.b).map(|(&v, &b)| v * b).collect()
    }

    /// Undo the balancing after dequantization: `k̂ / b` (the runtime-side
    /// inverse formulation described in the module docs).
    pub fn unbalance_key_into(&self, k: &mut [f32]) {
        debug_assert_eq!(k.len(), self.b.len());
        for (v, &b) in k.iter_mut().zip(&self.b) {
            *v /= b;
        }
    }

    /// Balance a query (eq. 4): `q / b`. Only used by the paper-literal
    /// formulation and the equivalence tests.
    pub fn balance_query(&self, q: &[f32]) -> Vec<f32> {
        debug_assert_eq!(q.len(), self.b.len());
        q.iter().zip(&self.b).map(|(&v, &b)| v / b).collect()
    }

    /// `1/b` vector, the form shipped to the fused attention HLO graph.
    pub fn inverse(&self) -> Vec<f32> {
        self.b.iter().map(|&b| 1.0 / b).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{dequantize, quantize, Precision, QuantParams};
    use crate::util::prop::{forall, gen_vec_normal, Config};
    use crate::prop_assert_close;

    fn dot(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn identity_balancer_is_noop() {
        let b = Balancer::identity(4);
        let k = vec![1.0, -2.0, 3.0, 0.5];
        assert_eq!(b.balance_key(&k), k);
        assert_eq!(b.balance_query(&k), k);
    }

    #[test]
    fn from_maxima_formula() {
        let b = Balancer::from_maxima(&[4.0, 1.0], &[1.0, 4.0]);
        assert!((b.b[0] - 2.0).abs() < 1e-6);
        assert!((b.b[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn zero_channels_stay_finite() {
        let b = Balancer::from_maxima(&[0.0, 5.0], &[0.0, 0.0]);
        assert!(b.b.iter().all(|v| v.is_finite() && *v > 0.0));
    }

    #[test]
    fn property_score_invariance_exact() {
        // (q/b)·(k·b) == q·k in exact arithmetic (up to fp roundoff).
        forall(Config::default().cases(200).name("balancer invariance"), |rng| {
            let d = *rng.choose(&[8usize, 16, 32]);
            let q = gen_vec_normal(rng, d, 1.0, 0.1);
            let k = gen_vec_normal(rng, d, 1.0, 0.1);
            let qmax: Vec<f32> = q.iter().map(|v| v.abs() + 0.1).collect();
            let kmax: Vec<f32> = k.iter().map(|v| v.abs() + 0.1).collect();
            let bal = Balancer::from_maxima(&qmax, &kmax);
            let s0 = dot(&q, &k);
            let s1 = dot(&bal.balance_query(&q), &bal.balance_key(&k));
            prop_assert_close!(s1, s0, 1e-4, 1e-4);
            Ok(())
        });
    }

    #[test]
    fn property_inverse_formulation_equivalent() {
        // Runtime form: q · (dequant(k·b)/b)  ==  (q/b) · dequant(k·b).
        forall(Config::default().cases(200).name("inverse form"), |rng| {
            let d = 16usize;
            let q = gen_vec_normal(rng, d, 1.0, 0.05);
            let k = gen_vec_normal(rng, d, 1.0, 0.05);
            let bal = Balancer::from_maxima(
                &q.iter().map(|v| v.abs().max(0.1)).collect::<Vec<_>>(),
                &k.iter().map(|v| v.abs().max(0.1)).collect::<Vec<_>>(),
            );
            let prm = QuantParams::new(Precision::Int2, 8);
            let kq = quantize(&bal.balance_key(&k), prm);
            let kdq = dequantize(&kq);

            let s_paper = dot(&bal.balance_query(&q), &kdq);
            let mut k_runtime = kdq.clone();
            bal.unbalance_key_into(&mut k_runtime);
            let s_runtime = dot(&q, &k_runtime);
            prop_assert_close!(s_runtime, s_paper, 1e-4, 1e-3);
            Ok(())
        });
    }

    #[test]
    fn balancer_reduces_int2_quant_error_under_outliers() {
        // The headline §3.2 effect. The balancer equalizes per-channel
        // magnitudes geometrically: k·b has range sqrt(qmax·kmax). It wins
        // when the query and key outlier *magnitudes differ per channel* —
        // key-heavy outlier channels get shrunk before quantization (paper:
        // "reduce the key outlier magnitudes"), query-heavy channels get
        // amplified in k so the channels the query amplifies are quantized
        // more accurately ("promote query outlier awareness").
        let d = 32usize;
        let mut rng = crate::util::rng::Pcg32::new(77);
        let mut worse = 0;
        let trials = 200;
        for i in 0..trials {
            let mut q = gen_vec_normal(&mut rng, d, 1.0, 0.0);
            let mut k = gen_vec_normal(&mut rng, d, 1.0, 0.0);
            if i % 2 == 0 {
                // key-side outliers dominate
                k[3] *= 30.0;
                k[17] *= 30.0;
                q[3] *= 3.0;
                q[17] *= 3.0;
            } else {
                // query-side outliers dominate on different channels
                q[5] *= 30.0;
                q[20] *= 30.0;
                k[9] *= 30.0;
            }
            let prm = QuantParams::new(Precision::Int2, 16);
            let s_true = dot(&q, &k);

            // unbalanced
            let k_plain = dequantize(&quantize(&k, prm));
            let err_plain = (dot(&q, &k_plain) - s_true).abs();

            // balanced
            let bal = Balancer::from_maxima(
                &q.iter().map(|v| v.abs()).collect::<Vec<_>>(),
                &k.iter().map(|v| v.abs()).collect::<Vec<_>>(),
            );
            let mut k_bal = dequantize(&quantize(&bal.balance_key(&k), prm));
            bal.unbalance_key_into(&mut k_bal);
            let err_bal = (dot(&q, &k_bal) - s_true).abs();

            if err_bal > err_plain {
                worse += 1;
            }
        }
        // Balancing should win in the strong majority of outlier-bearing cases.
        assert!(
            worse < trials / 4,
            "balancer lost {worse}/{trials} outlier cases"
        );
    }

    #[test]
    fn inverse_is_reciprocal() {
        let bal = Balancer::from_maxima(&[4.0, 9.0], &[1.0, 1.0]);
        let inv = bal.inverse();
        for (b, i) in bal.b.iter().zip(&inv) {
            assert!((b * i - 1.0).abs() < 1e-6);
        }
    }
}
