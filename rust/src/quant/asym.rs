//! Per-token asymmetric round-to-nearest quantization — paper eq. (1).
//!
//! A token's channel vector `x ∈ R^d` is split into groups of `group`
//! consecutive channels. Per group: `α = (max−min)/(2^N−1)`, `β = min`,
//! `code = round((x−β)/α) ∈ [0, 2^N−1]`, `x̂ = α·code + β`.
//!
//! The paper imposes a group size of **half the attention head dimension**
//! (§3.2) so a group never straddles the two RoPE-rotated halves of a head —
//! RoPE duplicates outlier channels across halves, and a group containing
//! one outlier half but not the other wastes dynamic range.
//!
//! Scale/zero metadata is held in f32 here but *stored* (logically and in
//! the memory accounting) as FP16, matching the paper; [`QuantParams::f16_meta`]
//! controls whether the dequantized values reflect FP16-rounded metadata.

use super::f16::round_f16;
use super::Precision;

/// Quantizer configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    pub precision: Precision,
    /// Channels per scale/zero group. Must divide the vector length.
    pub group: usize,
    /// Model FP16 storage of scale/zero metadata (paper-faithful default).
    pub f16_meta: bool,
}

impl QuantParams {
    pub fn new(precision: Precision, group: usize) -> Self {
        Self {
            precision,
            group,
            f16_meta: true,
        }
    }

    /// Number of groups for a vector of length `d`.
    pub fn groups(&self, d: usize) -> usize {
        assert!(
            d % self.group == 0,
            "group size {} must divide dim {}",
            self.group,
            d
        );
        d / self.group
    }
}

/// A quantized vector: unpacked codes plus per-group scale/zero.
///
/// `codes` are kept unpacked (one `u8` per element) at this level; the cache
/// tier packs them densely via [`super::packing`]. Keeping the two concerns
/// separate lets the property tests check each invariant independently.
#[derive(Debug, Clone, PartialEq)]
pub struct Quantized {
    pub params: QuantParams,
    pub codes: Vec<u8>,
    pub scales: Vec<f32>,
    pub zeros: Vec<f32>,
}

impl Quantized {
    pub fn len(&self) -> usize {
        self.codes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }
}

/// Quantize a channel vector. Panics if `group` does not divide `x.len()`.
pub fn quantize(x: &[f32], params: QuantParams) -> Quantized {
    assert!(params.precision.is_quantized(), "quantize with fp16 tier");
    let g = params.group;
    let n_groups = params.groups(x.len());
    let max_code = (params.precision.levels() - 1) as f32;

    let mut codes = vec![0u8; x.len()];
    let mut scales = Vec::with_capacity(n_groups);
    let mut zeros = Vec::with_capacity(n_groups);

    for gi in 0..n_groups {
        let chunk = &x[gi * g..(gi + 1) * g];
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in chunk {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let mut alpha = (hi - lo) / max_code;
        let mut beta = lo;
        if params.f16_meta {
            alpha = round_f16(alpha);
            beta = round_f16(beta);
        }
        if alpha > 0.0 {
            let inv = 1.0 / alpha;
            for (i, &v) in chunk.iter().enumerate() {
                let c = ((v - beta) * inv).round();
                codes[gi * g + i] = c.clamp(0.0, max_code) as u8;
            }
        }
        // alpha == 0 (constant group): codes stay 0, dequant = beta.
        scales.push(alpha);
        zeros.push(beta);
    }

    Quantized {
        params,
        codes,
        scales,
        zeros,
    }
}

/// Dequantize back to f32: `x̂ = α·code + β` per group.
pub fn dequantize(q: &Quantized) -> Vec<f32> {
    let g = q.params.group;
    let mut out = Vec::with_capacity(q.codes.len());
    for (gi, (&alpha, &beta)) in q.scales.iter().zip(&q.zeros).enumerate() {
        for &c in &q.codes[gi * g..(gi + 1) * g] {
            out.push(alpha * c as f32 + beta);
        }
    }
    out
}

/// Worst-case absolute reconstruction error for a given group's scale:
/// half a quantization step (plus FP16 metadata rounding slop).
///
/// The slop models FP16's 2^-11 relative rounding of α (amplified by the
/// precision's own max code — the value furthest from β) and of β. Using
/// the actual `precision.levels() - 1` instead of a hard-coded Int8 max
/// code keeps the bound tight for INT2/3/4.
pub fn error_bound(alpha: f32, beta: f32, precision: Precision, f16_meta: bool) -> f32 {
    debug_assert!(
        precision.is_quantized(),
        "error_bound is defined for code-book precisions, not {precision:?}"
    );
    let meta_slop = if f16_meta {
        // saturating_sub: Fp16 reports 0 levels; keep release builds sane
        // even if the debug_assert above is compiled out.
        let max_code = precision.levels().saturating_sub(1) as f32;
        (alpha.abs() * max_code + beta.abs()) / 2048.0
    } else {
        0.0
    };
    0.5 * alpha + meta_slop + 1e-6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{forall, gen_vec_normal, Config};

    fn params(p: Precision, group: usize) -> QuantParams {
        QuantParams {
            precision: p,
            group,
            f16_meta: false, // exact metadata for the tight error-bound tests
        }
    }

    #[test]
    fn int8_roundtrip_is_tight() {
        let x: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let q = quantize(&x, params(Precision::Int8, 64));
        let y = dequantize(&q);
        let alpha = q.scales[0];
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() <= 0.5 * alpha + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn constant_group_is_exact() {
        let x = vec![2.5f32; 16];
        let q = quantize(&x, params(Precision::Int2, 8));
        assert!(q.codes.iter().all(|&c| c == 0));
        let y = dequantize(&q);
        assert_eq!(y, x);
    }

    #[test]
    fn endpoints_are_exact_codes() {
        // min maps to code 0, max maps to max code, both reconstruct ~exactly.
        let x = vec![-1.0f32, 0.1, 0.2, 3.0];
        let q = quantize(&x, params(Precision::Int4, 4));
        assert_eq!(q.codes[0], 0);
        assert_eq!(q.codes[3], 15);
        let y = dequantize(&q);
        assert!((y[0] + 1.0).abs() < 1e-6);
        assert!((y[3] - 3.0).abs() < 1e-5);
    }

    #[test]
    fn codes_within_level_budget() {
        for p in [Precision::Int2, Precision::Int3, Precision::Int4, Precision::Int8] {
            let x: Vec<f32> = (0..32).map(|i| (i as f32).cos() * 10.0).collect();
            let q = quantize(&x, params(p, 16));
            let max = (p.levels() - 1) as u8;
            assert!(q.codes.iter().all(|&c| c <= max), "{p:?}");
        }
    }

    #[test]
    fn grouping_bounds_error_under_outliers() {
        // One outlier channel wrecks a single 64-wide group but only one of
        // eight 8-wide groups — grouped quantization must strictly reduce
        // total error.
        let mut x = vec![0.1f32; 64];
        for (i, v) in x.iter_mut().enumerate() {
            *v = (i as f32 * 0.7).sin();
        }
        x[5] = 40.0; // systematic outlier channel
        let q_coarse = quantize(&x, params(Precision::Int2, 64));
        let q_fine = quantize(&x, params(Precision::Int2, 8));
        let err = |q: &Quantized| -> f32 {
            dequantize(q)
                .iter()
                .zip(&x)
                .map(|(a, b)| (a - b).abs())
                .sum()
        };
        assert!(
            err(&q_fine) < err(&q_coarse) * 0.5,
            "fine {} coarse {}",
            err(&q_fine),
            err(&q_coarse)
        );
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn group_must_divide_dim() {
        quantize(&[1.0; 10], params(Precision::Int4, 4));
    }

    #[test]
    fn property_error_bound_all_precisions() {
        forall(
            Config::default().cases(300).name("quant error bound"),
            |rng| {
                let p = *rng.choose(&[
                    Precision::Int2,
                    Precision::Int3,
                    Precision::Int4,
                    Precision::Int8,
                ]);
                let group = *rng.choose(&[4usize, 8, 16, 32]);
                let n_groups = rng.gen_range(1, 4) as usize;
                let d = group * n_groups;
                let x = gen_vec_normal(rng, d, 2.0, 0.05);
                let prm = QuantParams {
                    precision: p,
                    group,
                    f16_meta: rng.gen_bool(0.5),
                };
                let q = quantize(&x, prm);
                let y = dequantize(&q);
                for gi in 0..n_groups {
                    // The precision-aware bound is strictly tighter than the
                    // old hard-coded Int8 slop for every sub-8-bit precision.
                    let bound = error_bound(q.scales[gi], q.zeros[gi], p, prm.f16_meta);
                    if p != Precision::Int8 && prm.f16_meta {
                        let loose = 0.5 * q.scales[gi]
                            + (q.scales[gi].abs() * 255.0 + q.zeros[gi].abs()) / 2048.0
                            + 1e-6;
                        prop_assert!(bound <= loose, "bound {bound} not tighter than {loose}");
                    }
                    for i in gi * group..(gi + 1) * group {
                        prop_assert!(
                            (x[i] - y[i]).abs() <= bound,
                            "err {} > bound {} (prec {:?}, group {})",
                            (x[i] - y[i]).abs(),
                            bound,
                            p,
                            group
                        );
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_dequant_monotone_in_codes() {
        // Within a group, a larger code must never dequantize lower.
        forall(Config::default().cases(100).name("monotone"), |rng| {
            let x = gen_vec_normal(rng, 16, 1.0, 0.1);
            let q = quantize(&x, params(Precision::Int3, 16));
            let y = dequantize(&q);
            for i in 0..16 {
                for j in 0..16 {
                    if q.codes[i] < q.codes[j] {
                        prop_assert!(y[i] <= y[j] + 1e-6);
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn f16_meta_matches_logical_storage() {
        // With f16_meta, scales/zeros must be exactly representable in f16.
        let x: Vec<f32> = (0..32).map(|i| (i as f32 * 1.17).sin() * 5.0).collect();
        let q = quantize(
            &x,
            QuantParams {
                precision: Precision::Int4,
                group: 16,
                f16_meta: true,
            },
        );
        for &s in q.scales.iter().chain(&q.zeros) {
            assert_eq!(s, round_f16(s), "metadata not f16-representable: {s}");
        }
    }
}
