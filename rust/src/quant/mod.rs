//! Quantization substrate for the mixed-precision KV cache.
//!
//! * [`asym`] — per-token asymmetric round-to-nearest quantization, paper
//!   eq. (1): `x̂ = α·round((x−β)/α) + β` with `α = (max−min)/(2^N−1)`,
//!   `β = min`, computed per group of channels within a token.
//! * [`packing`] — dense bit-packing of INT2/3/4/8 codes into `u32` words
//!   (the physical representation behind the logical memory accounting).
//! * [`balancer`] — the dynamic query/key outlier channel balancer, paper
//!   eq. (2)–(4).
//! * [`f16`] — IEEE binary16 conversion used to model the "FP16" tiers
//!   faithfully on an f32 runtime.
//! * [`perchannel`] — Appendix C per-channel key quantization alternative.

pub mod asym;
pub mod balancer;
pub mod f16;
pub mod packing;
pub mod perchannel;

pub use asym::{dequantize, quantize, QuantParams, Quantized};
pub use balancer::Balancer;

/// Storage precision of a cache tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// IEEE binary16 — the paper's high-precision tier default.
    Fp16,
    Int8,
    Int4,
    Int3,
    Int2,
}

impl Precision {
    /// Bits per stored element (payload only; group scale/zero overhead is
    /// accounted separately, see [`crate::kvcache::accounting`]).
    pub fn bits(self) -> u32 {
        match self {
            Precision::Fp16 => 16,
            Precision::Int8 => 8,
            Precision::Int4 => 4,
            Precision::Int3 => 3,
            Precision::Int2 => 2,
        }
    }

    /// Number of quantization levels for integer precisions.
    pub fn levels(self) -> u32 {
        match self {
            Precision::Fp16 => 0, // not a code-book precision
            p => 1 << p.bits(),
        }
    }

    /// Is this an integer code precision (needs scale/zero metadata)?
    pub fn is_quantized(self) -> bool {
        !matches!(self, Precision::Fp16)
    }

    /// Parse "fp16" | "int8" | "int4" | "int3" | "int2".
    pub fn parse(s: &str) -> Option<Precision> {
        Some(match s.to_ascii_lowercase().as_str() {
            "fp16" | "f16" => Precision::Fp16,
            "int8" | "i8" => Precision::Int8,
            "int4" | "i4" => Precision::Int4,
            "int3" | "i3" => Precision::Int3,
            "int2" | "i2" => Precision::Int2,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::Fp16 => "fp16",
            Precision::Int8 => "int8",
            Precision::Int4 => "int4",
            Precision::Int3 => "int3",
            Precision::Int2 => "int2",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_bits_and_levels() {
        assert_eq!(Precision::Fp16.bits(), 16);
        assert_eq!(Precision::Int4.bits(), 4);
        assert_eq!(Precision::Int2.levels(), 4);
        assert_eq!(Precision::Int3.levels(), 8);
        assert!(!Precision::Fp16.is_quantized());
        assert!(Precision::Int2.is_quantized());
    }

    #[test]
    fn precision_parse_roundtrip() {
        for p in [
            Precision::Fp16,
            Precision::Int8,
            Precision::Int4,
            Precision::Int3,
            Precision::Int2,
        ] {
            assert_eq!(Precision::parse(p.name()), Some(p));
        }
        assert_eq!(Precision::parse("int5"), None);
    }
}
