//! IEEE 754 binary16 conversion (no `half` crate offline).
//!
//! The paper's high-precision tier is FP16; the CPU PJRT runtime computes in
//! f32, so the cache manager *models* FP16 storage by round-tripping values
//! through binary16 on admission. Round-to-nearest-even, with proper
//! subnormal, infinity and NaN handling.

/// Convert f32 → binary16 bits (round-to-nearest-even).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        return sign | 0x7C00 | if mant != 0 { 0x0200 } else { 0 };
    }

    // Re-bias: f32 exp-127 → f16 exp-15
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow → inf
    }
    if unbiased >= -14 {
        // Normal f16. Keep 10 mantissa bits, round to nearest even.
        let mant16 = mant >> 13;
        let rem = mant & 0x1FFF;
        let mut h = sign | (((unbiased + 15) as u16) << 10) | mant16 as u16;
        if rem > 0x1000 || (rem == 0x1000 && (mant16 & 1) == 1) {
            h = h.wrapping_add(1); // may carry into exponent — correct behaviour
        }
        return h;
    }
    if unbiased >= -25 {
        // Subnormal f16: value = mant16 × 2^-24, so
        // mant16 = round(full × 2^(unbiased+1) / 2^24) = full >> shift.
        let shift = (-1 - unbiased) as u32; // 14..=24
        let full = 0x0080_0000 | mant; // implicit leading 1
        let mant16 = full >> shift;
        let rem = full & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut h = sign | mant16 as u16;
        if rem > half || (rem == half && (mant16 & 1) == 1) {
            h = h.wrapping_add(1);
        }
        return h;
    }
    sign // underflow → signed zero
}

/// Convert binary16 bits → f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign // zero
        } else {
            // subnormal: value = mant × 2^-24; normalize the mantissa.
            // After `s` left-shifts bit 10 is set and the value equals
            // 1.f × 2^(-14-s), i.e. biased f32 exponent 113 - s = 114 + e.
            let mut e = -1i32;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03FF;
            sign | (((114 + e) as u32) << 23) | (m << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13) // inf / nan
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round an f32 through binary16 (the "store in FP16" model).
pub fn round_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Round a slice in place through binary16.
pub fn round_f16_slice(xs: &mut [f32]) {
    for x in xs {
        *x = round_f16(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_values_roundtrip() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 1024.0, 65504.0] {
            assert_eq!(round_f16(v), v, "value {v} should be f16-exact");
        }
    }

    #[test]
    fn known_bit_patterns() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF); // max finite f16
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f16_bits_to_f32(0x3C00), 1.0);
        assert_eq!(f16_bits_to_f32(0x7C00), f32::INFINITY);
    }

    #[test]
    fn overflow_to_infinity() {
        assert_eq!(round_f16(1e6), f32::INFINITY);
        assert_eq!(round_f16(-1e6), f32::NEG_INFINITY);
    }

    #[test]
    fn underflow_to_zero_and_subnormals() {
        assert_eq!(round_f16(1e-10), 0.0);
        // smallest f16 subnormal = 2^-24
        let tiny = 2.0f32.powi(-24);
        assert_eq!(round_f16(tiny), tiny);
        // 2^-25 rounds to zero (ties-to-even)
        assert_eq!(round_f16(2.0f32.powi(-25)), 0.0);
    }

    #[test]
    fn nan_preserved() {
        assert!(round_f16(f32::NAN).is_nan());
    }

    #[test]
    fn relative_error_bound_for_normals() {
        // For f16-normal range, relative error <= 2^-11.
        let mut seed = 0x1234_5678u32;
        for _ in 0..10_000 {
            seed = seed.wrapping_mul(1664525).wrapping_add(1013904223);
            let v = ((seed >> 8) as f32 / (1 << 24) as f32) * 100.0 - 50.0;
            if v.abs() < 1e-2 {
                continue;
            }
            let r = round_f16(v);
            assert!(
                ((r - v) / v).abs() <= 1.0 / 2048.0 + 1e-7,
                "v={v} r={r}"
            );
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10;
        // nearest-even picks 1.0 (mantissa even).
        let halfway = 1.0 + 2.0f32.powi(-11);
        assert_eq!(round_f16(halfway), 1.0);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9 → picks 1+2^-9.
        let halfway2 = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(round_f16(halfway2), 1.0 + 2.0f32.powi(-9));
    }
}
