//! Dense bit-packing of quantization codes.
//!
//! The retained (low-precision) cache tier stores codes packed back-to-back
//! in `u32` words — INT3 codes straddle word boundaries, so the packer is a
//! general little-endian bit stream. This is the physical layout behind the
//! logical "cache size %" accounting and the unpack is on the decode hot
//! path (see EXPERIMENTS.md §Perf for the word-at-a-time fast paths).

/// Number of `u32` words needed for `n` codes of `bits` width.
pub fn packed_words(n: usize, bits: u32) -> usize {
    ((n as u64 * bits as u64 + 31) / 32) as usize
}

/// Pack `codes` (each `< 2^bits`) into a little-endian bit stream.
// lint: hot-path-alloc-free-ok(fn): allocating API variant; hot paths use pack_into-style scratch
pub fn pack(codes: &[u8], bits: u32) -> Vec<u32> {
    assert!((1..=8).contains(&bits), "bits must be in 1..=8");
    let mask = ((1u32 << bits) - 1) as u8;
    let mut out = vec![0u32; packed_words(codes.len(), bits)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(c & !mask == 0, "code {c} exceeds {bits} bits");
        let word = bitpos >> 5;
        let off = (bitpos & 31) as u32;
        out[word] |= (c as u32) << off;
        // spill into the next word when the field straddles the boundary
        if off + bits > 32 {
            out[word + 1] |= (c as u32) >> (32 - off);
        }
        bitpos += bits as usize;
    }
    out
}

/// Unpack `n` codes of `bits` width from a packed stream.
// lint: hot-path-alloc-free-ok(fn): allocating variant; decode uses unpack_into/unpack_dequant_into
pub fn unpack(words: &[u32], bits: u32, n: usize) -> Vec<u8> {
    let mut out = vec![0u8; n];
    unpack_into(words, bits, &mut out);
    out
}

/// Unpack into a caller-provided buffer (hot path — avoids allocation).
pub fn unpack_into(words: &[u32], bits: u32, out: &mut [u8]) {
    assert!((1..=8).contains(&bits), "bits must be in 1..=8");
    debug_assert!(packed_words(out.len(), bits) <= words.len(), "short input");
    match bits {
        2 => unpack_pow2::<2, 16>(words, out),
        4 => unpack_pow2::<4, 8>(words, out),
        8 => unpack_pow2::<8, 4>(words, out),
        _ => unpack_generic(words, bits, out),
    }
}

/// Fast path for power-of-two widths: fields never straddle word boundaries,
/// so each word yields exactly `PER` codes with shift/mask only.
fn unpack_pow2<const BITS: u32, const PER: usize>(words: &[u32], out: &mut [u8]) {
    let mask = (1u32 << BITS) - 1;
    let mut i = 0usize;
    let n = out.len();
    let full_words = n / PER;
    for (w, &word) in words.iter().enumerate().take(full_words) {
        debug_assert_eq!(w * PER, i);
        let mut v = word;
        for k in 0..PER {
            out[i + k] = (v & mask) as u8;
            v >>= BITS;
        }
        i += PER;
    }
    // tail
    if i < n {
        let mut v = words[full_words];
        while i < n {
            out[i] = (v & mask) as u8;
            v >>= BITS;
            i += 1;
        }
    }
}

fn unpack_generic(words: &[u32], bits: u32, out: &mut [u8]) {
    let mask = (1u32 << bits) - 1;
    let mut bitpos = 0usize;
    for o in out.iter_mut() {
        let word = bitpos >> 5;
        let off = (bitpos & 31) as u32;
        let mut v = words[word] >> off;
        if off + bits > 32 {
            v |= words[word + 1] << (32 - off);
        }
        *o = (v & mask) as u8;
        bitpos += bits as usize;
    }
}

/// Unpack codes and dequantize in one fused pass:
/// `out[i] = zero[i/group] + scale[i/group] * code_i`.
///
/// This is the decode hot path's input-assembly kernel — the rust analogue
/// of the paper's fused weight-only-quant GEMV load stage.
pub fn unpack_dequant_into(
    words: &[u32],
    bits: u32,
    scales: &[f32],
    zeros: &[f32],
    group: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len() % group, 0);
    debug_assert_eq!(out.len() / group, scales.len());
    let mask = (1u32 << bits) - 1;
    let mut bitpos = 0usize;
    for (gi, chunk) in out.chunks_mut(group).enumerate() {
        let (alpha, beta) = (scales[gi], zeros[gi]);
        for o in chunk.iter_mut() {
            let word = bitpos >> 5;
            let off = (bitpos & 31) as u32;
            let mut v = words[word] >> off;
            if off + bits > 32 {
                v |= words[word + 1] << (32 - off);
            }
            *o = alpha * (v & mask) as f32 + beta;
            bitpos += bits as usize;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{forall, Config};

    #[test]
    fn packed_words_math() {
        assert_eq!(packed_words(0, 3), 0);
        assert_eq!(packed_words(10, 3), 1); // 30 bits
        assert_eq!(packed_words(11, 3), 2); // 33 bits
        assert_eq!(packed_words(16, 2), 1);
        assert_eq!(packed_words(17, 2), 2);
        assert_eq!(packed_words(4, 8), 1);
    }

    #[test]
    fn roundtrip_all_widths_exhaustive_small() {
        for bits in 1..=8u32 {
            let max = ((1u32 << bits) - 1) as u8;
            let codes: Vec<u8> = (0..97).map(|i| (i % (max as usize + 1)) as u8).collect();
            let packed = pack(&codes, bits);
            let back = unpack(&packed, bits, codes.len());
            assert_eq!(back, codes, "bits={bits}");
        }
    }

    #[test]
    fn int3_straddles_word_boundaries() {
        // 11 codes * 3 bits = 33 bits: code 10 straddles words 0 and 1.
        let codes: Vec<u8> = vec![7, 0, 5, 2, 7, 1, 6, 3, 4, 7, 5];
        let packed = pack(&codes, 3);
        assert_eq!(packed.len(), 2);
        assert_eq!(unpack(&packed, 3, 11), codes);
    }

    #[test]
    fn property_pack_unpack_identity() {
        forall(Config::default().cases(400).name("pack identity"), |rng| {
            let bits = rng.gen_range(1, 8) as u32;
            let n = rng.gen_range(0, 300) as usize;
            let max = (1u32 << bits) - 1;
            let codes: Vec<u8> = (0..n).map(|_| rng.gen_below(max + 1) as u8).collect();
            let packed = pack(&codes, bits);
            prop_assert!(packed.len() == packed_words(n, bits));
            let back = unpack(&packed, bits, n);
            prop_assert!(back == codes, "mismatch at bits={bits} n={n}");
            Ok(())
        });
    }

    /// Exhaustive-width round-trip property: for EVERY width 1..=8 (the
    /// generic straddling path INT3/5/6/7 included — `gen_range(1, 8)` in
    /// the older property never drew 8, and random widths under-sample the
    /// odd ones) and deliberately non-word-aligned lengths, pack→unpack is
    /// the identity, the packed word count is exactly `packed_words`, and
    /// that count is tight (no slack word).
    #[test]
    fn property_roundtrip_every_width_and_unaligned_lengths() {
        forall(Config::default().cases(64).name("pack all widths"), |rng| {
            for bits in 1..=8u32 {
                // Bias lengths toward boundary-straddling cases: exact
                // word multiples ±1 and small random sizes.
                let per_word = 32 / bits as usize; // codes in a full word (floor)
                let candidates = [
                    per_word.saturating_sub(1),
                    per_word + 1,
                    2 * per_word + 1,
                    1 + rng.gen_below(97) as usize,
                    rng.gen_below(300) as usize,
                ];
                let n = *rng.choose(&candidates);
                let max = (1u32 << bits) - 1;
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.gen_below(max + 1) as u8).collect();
                let packed = pack(&codes, bits);
                prop_assert!(
                    packed.len() == packed_words(n, bits),
                    "len {} != packed_words({n}, {bits}) = {}",
                    packed.len(),
                    packed_words(n, bits)
                );
                // Tightness: packed_words is the minimal word count.
                prop_assert!(
                    packed.len() as u64 * 32 >= n as u64 * bits as u64,
                    "too few words at bits={bits} n={n}"
                );
                prop_assert!(
                    (packed.len() as u64) * 32 < n as u64 * bits as u64 + 32,
                    "slack word at bits={bits} n={n}"
                );
                let back = unpack(&packed, bits, n);
                prop_assert!(back == codes, "roundtrip mismatch at bits={bits} n={n}");
                // unpack_into on a caller buffer agrees with unpack.
                let mut buf = vec![0xFFu8; n];
                unpack_into(&packed, bits, &mut buf);
                prop_assert!(buf == codes, "unpack_into mismatch at bits={bits} n={n}");
            }
            Ok(())
        });
    }

    #[test]
    fn fused_unpack_dequant_matches_two_step() {
        forall(Config::default().cases(200).name("fused dequant"), |rng| {
            let bits = *rng.choose(&[2u32, 3, 4, 8]);
            let group = *rng.choose(&[4usize, 8, 16]);
            let n_groups = rng.gen_range(1, 6) as usize;
            let n = group * n_groups;
            let max = (1u32 << bits) - 1;
            let codes: Vec<u8> = (0..n).map(|_| rng.gen_below(max + 1) as u8).collect();
            let scales: Vec<f32> = (0..n_groups).map(|_| rng.gen_f32_range(0.01, 2.0)).collect();
            let zeros: Vec<f32> = (0..n_groups).map(|_| rng.gen_f32_range(-3.0, 3.0)).collect();
            let packed = pack(&codes, bits);

            let mut fused = vec![0.0f32; n];
            unpack_dequant_into(&packed, bits, &scales, &zeros, group, &mut fused);

            let unpacked = unpack(&packed, bits, n);
            for i in 0..n {
                let expect = scales[i / group] * unpacked[i] as f32 + zeros[i / group];
                prop_assert!((fused[i] - expect).abs() < 1e-6);
            }
            Ok(())
        });
    }

    #[test]
    fn empty_input() {
        assert_eq!(pack(&[], 4), Vec::<u32>::new());
        assert_eq!(unpack(&[], 4, 0), Vec::<u8>::new());
    }

    #[test]
    fn unpack_into_reuses_buffer() {
        let codes = vec![1u8, 2, 3, 0, 3, 1];
        let packed = pack(&codes, 2);
        let mut buf = vec![9u8; 6];
        unpack_into(&packed, 2, &mut buf);
        assert_eq!(buf, codes);
    }
}
