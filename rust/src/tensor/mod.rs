//! Minimal row-major host tensor.
//!
//! The runtime passes tensors to PJRT as `xla::Literal`s; everything else in
//! the crate (cache manager, quantizer, eval drivers) works on this plain
//! host type. Deliberately small: shape + contiguous `Vec<T>`, constructors,
//! indexing helpers, and a few bulk ops — not an ndarray clone.

use std::fmt;

/// Dense row-major tensor over element type `T`.
#[derive(Clone, PartialEq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Vec<T>,
}

/// f32 tensor — activations, scales, masks.
pub type TensorF32 = Tensor<f32>;
/// i64 tensor — token ids, positions (HLO S64).
pub type TensorI64 = Tensor<i64>;
/// u8 tensor — packed quantized codes.
pub type TensorU8 = Tensor<u8>;

impl<T: Clone + Default> Tensor<T> {
    /// All-default (zero) tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: vec![T::default(); n],
        }
    }
}

impl<T> Tensor<T> {
    /// Wrap an existing buffer. Panics if `data.len() != prod(shape)`.
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(
            data.len(),
            n,
            "Tensor::from_vec: data len {} != shape {:?} (= {})",
            data.len(),
            shape,
            n
        );
        Self {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Scalar (rank-0) tensor.
    pub fn scalar(v: T) -> Self {
        Self {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Linear offset of a multi-dimensional index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len(), "index rank mismatch");
        let mut off = 0;
        for (d, &i) in idx.iter().enumerate() {
            debug_assert!(i < self.shape[d], "index {i} out of bounds dim {d}");
            off = off * self.shape[d] + i;
        }
        off
    }

    /// Element access by multi-index.
    pub fn at(&self, idx: &[usize]) -> &T {
        &self.data[self.offset(idx)]
    }

    pub fn at_mut(&mut self, idx: &[usize]) -> &mut T {
        let off = self.offset(idx);
        &mut self.data[off]
    }

    /// Reinterpret the shape (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape: element count mismatch");
        self.shape = shape.to_vec();
        self
    }

    /// Contiguous sub-slice along the leading axis: rows `lo..hi`.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Tensor<T>
    where
        T: Clone,
    {
        assert!(self.rank() >= 1 && lo <= hi && hi <= self.shape[0]);
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Tensor::from_vec(&shape, self.data[lo * row..hi * row].to_vec())
    }

    /// Map elements producing a new tensor.
    pub fn map<U>(&self, f: impl Fn(&T) -> U) -> Tensor<U> {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(f).collect(),
        }
    }
}

impl TensorF32 {
    /// Max |x| over the whole tensor.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0, |m, &x| m.max(x.abs()))
    }

    /// Argmax over the last axis for a rank-2 tensor `[rows, cols]`.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.rank(), 2, "argmax_rows expects rank-2");
        let (rows, cols) = (self.shape[0], self.shape[1]);
        (0..rows)
            .map(|r| {
                let row = &self.data[r * cols..(r + 1) * cols];
                // total_cmp: a NaN logit deterministically wins the argmax
                // (NaN sorts greatest) instead of the inconsistent
                // comparator picking whichever index the sort happened to
                // visit last.
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }

    /// Mean absolute difference against another tensor of the same shape.
    pub fn mean_abs_diff(&self, other: &TensorF32) -> f32 {
        assert_eq!(self.shape, other.shape);
        if self.data.is_empty() {
            return 0.0;
        }
        let s: f64 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum();
        (s / self.data.len() as f64) as f32
    }
}

impl<T: fmt::Debug> fmt::Debug for Tensor<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)
        } else {
            write!(f, " [{:?}, {:?}, ... ({} elems)]", self.data[0], self.data[1], self.data.len())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = TensorF32::zeros(&[2, 3, 4]);
        assert_eq!(t.shape(), &[2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn indexing_row_major() {
        let t = Tensor::from_vec(&[2, 3], (0..6).collect::<Vec<i64>>());
        assert_eq!(*t.at(&[0, 0]), 0);
        assert_eq!(*t.at(&[0, 2]), 2);
        assert_eq!(*t.at(&[1, 0]), 3);
        assert_eq!(*t.at(&[1, 2]), 5);
    }

    #[test]
    #[should_panic]
    fn from_vec_len_mismatch_panics() {
        let _ = Tensor::from_vec(&[2, 2], vec![1.0f32; 3]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[6], (0..6).collect::<Vec<i64>>()).reshape(&[3, 2]);
        assert_eq!(*t.at(&[2, 1]), 5);
    }

    #[test]
    fn slice_rows_extracts_contiguous_block() {
        let t = Tensor::from_vec(&[4, 2], (0..8).collect::<Vec<i64>>());
        let s = t.slice_rows(1, 3);
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[2, 3, 4, 5]);
    }

    #[test]
    fn argmax_rows_picks_max() {
        let t = Tensor::from_vec(&[2, 3], vec![0.1, 0.9, 0.3, 2.0, -1.0, 1.5]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    /// Regression: the old `partial_cmp(..).unwrap_or(Equal)` comparator
    /// was inconsistent under NaN — `max_by` could return whichever index
    /// the scan happened to end on. With `total_cmp`, a NaN logit
    /// deterministically wins regardless of its position in the row.
    #[test]
    fn argmax_rows_nan_policy_is_deterministic() {
        let data = vec![0.1, f32::NAN, 0.9, f32::NAN, 0.2, 0.3, 0.5, 0.9, 0.1];
        let t = Tensor::from_vec(&[3, 3], data);
        assert_eq!(t.argmax_rows(), vec![1, 0, 1]);
    }

    #[test]
    fn scalar_tensor() {
        let t = TensorI64::scalar(7);
        assert_eq!(t.rank(), 0);
        assert_eq!(t.numel(), 1);
        assert_eq!(t.data()[0], 7);
    }

    #[test]
    fn map_and_abs_max() {
        let t = Tensor::from_vec(&[3], vec![-2.0f32, 1.0, 0.5]);
        assert_eq!(t.abs_max(), 2.0);
        let u = t.map(|x| x * 2.0);
        assert_eq!(u.data(), &[-4.0, 2.0, 1.0]);
    }

    #[test]
    fn mean_abs_diff_zero_for_identical() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(t.mean_abs_diff(&t.clone()), 0.0);
    }
}
