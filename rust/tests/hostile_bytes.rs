//! Hostile-bytes property tests for the wire surface — the dynamic
//! companion to mikv-lint's `panic-free-serving` rule (see
//! ARCHITECTURE.md § "Invariants & lint catalog").
//!
//! Whatever arrives on the socket, `Json::parse` and `proto::decode_line`
//! must *return* — `Ok` or a structured `Err`, either is fine; a panic
//! would take down a connection's reader thread and, transitively, every
//! request multiplexed onto it. The generators cover byte-level mutations
//! of valid v1 frames (flips, truncations, insertions, splices) and raw
//! garbage that was never JSON to begin with.

use mikv::server::proto::{decode_line, RequestBuilder};
use mikv::util::json::Json;
use mikv::util::prop::{forall, Config};
use mikv::util::rng::Pcg32;

/// A syntactically valid v1 frame of a random op shape.
fn valid_frame(rng: &mut Pcg32) -> String {
    let id = rng.next_u32() as u64;
    let n = rng.gen_range(0, 8) as usize;
    let prompt: Vec<i64> = (0..n).map(|_| rng.gen_range(0, 1000)).collect();
    match rng.gen_range(0, 5) {
        0 => RequestBuilder::generate(id)
            .prompt(&prompt)
            .max_new(rng.gen_range(1, 16) as usize)
            .build(),
        1 => RequestBuilder::append(id, rng.next_u32() as u64).prompt(&prompt).build(),
        2 => RequestBuilder::cancel(id, rng.next_u32() as u64).build(),
        3 => RequestBuilder::stats(id).build(),
        _ => RequestBuilder::generate(id).prompt(&prompt).legacy().build(),
    }
}

/// Byte-level mutation: flips, deletions, insertions and splices, applied
/// a random number of times.
fn mutate(rng: &mut Pcg32, bytes: &mut Vec<u8>) {
    let edits = 1 + rng.gen_below(8) as usize;
    for _ in 0..edits {
        if bytes.is_empty() {
            bytes.push(rng.next_u32() as u8);
            continue;
        }
        let pos = rng.gen_below(bytes.len() as u32) as usize;
        match rng.gen_below(4) {
            0 => bytes[pos] = rng.next_u32() as u8,
            1 => {
                bytes.truncate(pos);
            }
            2 => bytes.insert(pos, rng.next_u32() as u8),
            _ => {
                // splice a fragment of the frame over itself
                let src = rng.gen_below(bytes.len() as u32) as usize;
                let b = bytes[src];
                bytes[pos] = b;
            }
        }
    }
}

/// Feed one line to both parsers; only a panic can fail this.
fn never_panics(line: &str) {
    let _ = Json::parse(line);
    let _ = decode_line(line);
}

#[test]
fn mutated_v1_frames_never_panic_the_parsers() {
    forall(Config::default().cases(500).seed(0xB0B5).name("mutated v1 frames"), |rng| {
        let mut bytes = valid_frame(rng).into_bytes();
        mutate(rng, &mut bytes);
        let line = String::from_utf8_lossy(&bytes);
        never_panics(line.trim());
        Ok(())
    });
}

#[test]
fn raw_garbage_never_panics_the_parsers() {
    forall(Config::default().cases(500).seed(0xDEAD).name("raw garbage"), |rng| {
        let n = rng.gen_below(128) as usize;
        let bytes: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
        let line = String::from_utf8_lossy(&bytes);
        never_panics(&line);
        Ok(())
    });
}

#[test]
fn adversarial_json_shapes_never_panic() {
    // Hand-picked shapes that historically break naive parsers: deep
    // nesting, truncated escapes, huge numbers, wrong field types.
    let cases = [
        "",
        "{",
        "}",
        "[",
        "\"",
        "{\"v\":1",
        "{\"v\":9999999999999999999999999,\"op\":\"generate\"}",
        "{\"v\":1,\"op\":\"generate\",\"id\":-1}",
        "{\"v\":1,\"op\":\"generate\",\"id\":\"not a number\"}",
        "{\"v\":1,\"op\":\"generate\",\"prompt\":[1,2,\"x\"]}",
        "{\"v\":1,\"op\":\"generate\",\"prompt\":{\"a\":1}}",
        "{\"v\":1,\"op\":\"nope\",\"id\":1}",
        "{\"v\":2,\"op\":\"generate\",\"id\":1}",
        "{\"prompt\":[1],\"max_new\":1e309}",
        "[[[[[[[[[[[[[[[[[[[[[[[[[[[[[[[[]]]]]]]]]]]]]]]]]]]]]]]]]]]]]]]]",
        "{\"a\":\"\\u12\"}",
        "{\"a\":\"\\",
        "nul\u{0}byte",
    ];
    for c in cases {
        never_panics(c);
    }
}
