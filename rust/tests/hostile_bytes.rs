//! Hostile-bytes property tests for the wire surface — the dynamic
//! companion to mikv-lint's `panic-free-serving` rule (see
//! ARCHITECTURE.md § "Invariants & lint catalog").
//!
//! Whatever arrives on the socket, `Json::parse` and `proto::decode_line`
//! must *return* — `Ok` or a structured `Err`, either is fine; a panic
//! would take down a connection's reader thread and, transitively, every
//! request multiplexed onto it. The generators cover byte-level mutations
//! of valid v1 frames (flips, truncations, insertions, splices) and raw
//! garbage that was never JSON to begin with.

use mikv::kvcache::spill::{self, Writer};
use mikv::kvcache::{BufferPool, SpillError};
use mikv::model::{CacheMode, Session, SessionCache};
use mikv::runtime::ModelDims;
use mikv::server::proto::{decode_line, RequestBuilder};
use mikv::util::json::Json;
use mikv::util::prop::{forall, Config};
use mikv::util::rng::Pcg32;

/// A syntactically valid v1 frame of a random op shape.
fn valid_frame(rng: &mut Pcg32) -> String {
    let id = rng.next_u32() as u64;
    let n = rng.gen_range(0, 8) as usize;
    let prompt: Vec<i64> = (0..n).map(|_| rng.gen_range(0, 1000)).collect();
    match rng.gen_range(0, 5) {
        0 => RequestBuilder::generate(id)
            .prompt(&prompt)
            .max_new(rng.gen_range(1, 16) as usize)
            .build(),
        1 => RequestBuilder::append(id, rng.next_u32() as u64).prompt(&prompt).build(),
        2 => RequestBuilder::cancel(id, rng.next_u32() as u64).build(),
        3 => RequestBuilder::stats(id).build(),
        _ => RequestBuilder::generate(id).prompt(&prompt).legacy().build(),
    }
}

/// Byte-level mutation: flips, deletions, insertions and splices, applied
/// a random number of times.
fn mutate(rng: &mut Pcg32, bytes: &mut Vec<u8>) {
    let edits = 1 + rng.gen_below(8) as usize;
    for _ in 0..edits {
        if bytes.is_empty() {
            bytes.push(rng.next_u32() as u8);
            continue;
        }
        let pos = rng.gen_below(bytes.len() as u32) as usize;
        match rng.gen_below(4) {
            0 => bytes[pos] = rng.next_u32() as u8,
            1 => {
                bytes.truncate(pos);
            }
            2 => bytes.insert(pos, rng.next_u32() as u8),
            _ => {
                // splice a fragment of the frame over itself
                let src = rng.gen_below(bytes.len() as u32) as usize;
                let b = bytes[src];
                bytes[pos] = b;
            }
        }
    }
}

/// Feed one line to both parsers; only a panic can fail this.
fn never_panics(line: &str) {
    let _ = Json::parse(line);
    let _ = decode_line(line);
}

#[test]
fn mutated_v1_frames_never_panic_the_parsers() {
    forall(Config::default().cases(500).seed(0xB0B5).name("mutated v1 frames"), |rng| {
        let mut bytes = valid_frame(rng).into_bytes();
        mutate(rng, &mut bytes);
        let line = String::from_utf8_lossy(&bytes);
        never_panics(line.trim());
        Ok(())
    });
}

#[test]
fn raw_garbage_never_panics_the_parsers() {
    forall(Config::default().cases(500).seed(0xDEAD).name("raw garbage"), |rng| {
        let n = rng.gen_below(128) as usize;
        let bytes: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
        let line = String::from_utf8_lossy(&bytes);
        never_panics(&line);
        Ok(())
    });
}

#[test]
fn adversarial_json_shapes_never_panic() {
    // Hand-picked shapes that historically break naive parsers: deep
    // nesting, truncated escapes, huge numbers, wrong field types.
    let cases = [
        "",
        "{",
        "}",
        "[",
        "\"",
        "{\"v\":1",
        "{\"v\":9999999999999999999999999,\"op\":\"generate\"}",
        "{\"v\":1,\"op\":\"generate\",\"id\":-1}",
        "{\"v\":1,\"op\":\"generate\",\"id\":\"not a number\"}",
        "{\"v\":1,\"op\":\"generate\",\"prompt\":[1,2,\"x\"]}",
        "{\"v\":1,\"op\":\"generate\",\"prompt\":{\"a\":1}}",
        "{\"v\":1,\"op\":\"nope\",\"id\":1}",
        "{\"v\":2,\"op\":\"generate\",\"id\":1}",
        "{\"prompt\":[1],\"max_new\":1e309}",
        "[[[[[[[[[[[[[[[[[[[[[[[[[[[[[[[[]]]]]]]]]]]]]]]]]]]]]]]]]]]]]]]]",
        "{\"a\":\"\\u12\"}",
        "{\"a\":\"\\",
        "nul\u{0}byte",
    ];
    for c in cases {
        never_panics(c);
    }
}

// ---------------------------------------------------------------------
// Cold-tier snapshot codec (rust/src/kvcache/spill.rs) — held to the same
// contract as the wire surface: whatever bytes come back off disk,
// `decode_session` must return a structured `SpillError`, never panic,
// because restore runs on the serving path (a corrupt snapshot maps onto
// `session_not_found`, not a downed worker).
// ---------------------------------------------------------------------

fn spill_dims() -> ModelDims {
    ModelDims {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_q_heads: 4,
        n_kv_heads: 2,
        d_head: 8,
        d_ff: 64,
        max_seq: 32,
        quant_group: 4,
        params: 0,
    }
}

/// Build a live session of a random cache mode with a few prefilled
/// tokens and encode it into a valid snapshot frame.
fn valid_snapshot(rng: &mut Pcg32) -> Vec<u8> {
    let dm = spill_dims();
    let mode_str = *rng.choose(&["full", "oracle:4", "mikv:0.5:int4", "mikv:0.25:int2"]);
    let mode = CacheMode::parse(mode_str, &dm).expect("parsable mode");
    let mut sess = Session::new(rng.next_u64(), &dm, mode).expect("session");
    let planes = dm.planes();
    let d = dm.d_head;
    let t0 = 2 + rng.gen_below(6) as usize;
    let k: Vec<f32> = (0..planes * t0 * d).map(|_| rng.gen_normal()).collect();
    let v: Vec<f32> = (0..planes * t0 * d).map(|_| rng.gen_normal()).collect();
    match &mut sess.cache {
        SessionCache::Mikv(m) => {
            let acc: Vec<f32> = (0..planes * t0).map(|_| rng.gen_f32()).collect();
            let qmax: Vec<f32> = (0..planes * d).map(|_| rng.gen_f32() + 0.5).collect();
            let kmax: Vec<f32> = (0..planes * d).map(|_| rng.gen_f32() + 0.5).collect();
            m.ingest_prefill(t0, &k, &v, &acc, &qmax, &kmax);
        }
        SessionCache::Full(f) => f.ingest_prefill(t0, &k, &v),
    }
    sess.tokens = (0..t0 as i64).collect();
    sess.prompt_len = t0;
    sess.last_token = (t0 - 1) as i64;
    spill::encode_session(&sess).expect("valid session encodes")
}

/// Decode hostile snapshot bytes; only a panic can fail this.
fn decode_never_panics(bytes: &[u8]) -> Result<(), SpillError> {
    spill::decode_session(bytes, &spill_dims(), &BufferPool::new()).map(|_| ())
}

#[test]
fn truncated_snapshots_fail_structurally_at_every_cut() {
    let mut rng = Pcg32::new(0x51C0);
    let frame = valid_snapshot(&mut rng);
    assert!(decode_never_panics(&frame).is_ok(), "uncut frame must decode");
    for cut in 0..frame.len() {
        assert!(
            decode_never_panics(&frame[..cut]).is_err(),
            "truncation at {cut}/{} decoded",
            frame.len()
        );
    }
}

#[test]
fn single_byte_corruption_is_always_rejected() {
    // Any single-byte change must be caught: in the payload by the FNV
    // checksum, in the header by the magic/version/length/checksum checks.
    let mut rng = Pcg32::new(0x51C1);
    let frame = valid_snapshot(&mut rng);
    for pos in 0..frame.len() {
        for mask in [0x01u8, 0x80] {
            let mut f = frame.clone();
            f[pos] ^= mask;
            assert!(
                decode_never_panics(&f).is_err(),
                "flip {mask:#x} at byte {pos} decoded"
            );
        }
    }
}

#[test]
fn mutated_snapshots_never_panic_the_decoder() {
    forall(Config::default().cases(150).seed(0x51C2).name("mutated snapshots"), |rng| {
        let mut bytes = valid_snapshot(rng);
        mutate(rng, &mut bytes);
        let _ = decode_never_panics(&bytes);
        Ok(())
    });
}

#[test]
fn raw_garbage_never_panics_the_decoder() {
    forall(Config::default().cases(300).seed(0x51C3).name("garbage snapshots"), |rng| {
        let n = rng.gen_below(256) as usize;
        let bytes: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
        let _ = decode_never_panics(&bytes);
        Ok(())
    });
}

#[test]
fn future_version_frames_are_rejected_with_the_version_error() {
    let mut f = Vec::new();
    f.extend_from_slice(&spill::MAGIC);
    f.extend_from_slice(&2u32.to_le_bytes());
    f.extend_from_slice(&0u64.to_le_bytes());
    f.extend_from_slice(&spill::checksum(&[]).to_le_bytes());
    assert_eq!(
        decode_never_panics(&f).err(),
        Some(SpillError::UnsupportedVersion(2))
    );
}

#[test]
fn checksum_valid_but_malformed_payloads_fail_structurally() {
    // Frames whose header and checksum are perfectly valid but whose
    // payload lies — the cases a checksum alone cannot catch.
    let empty = Writer::with_capacity(0).into_frame();
    assert!(matches!(
        decode_never_panics(&empty).err(),
        Some(SpillError::Truncated { .. })
    ));

    // Token count far beyond the payload: rejected up front, before any
    // allocation sized from the hostile length.
    let mut w = Writer::with_capacity(16);
    w.put_u64(7); // id
    w.put_u64(u64::MAX); // n_tokens
    assert!(matches!(
        decode_never_panics(&w.into_frame()).err(),
        Some(SpillError::Truncated { .. })
    ));

    // Session header with an out-of-range `done` flag.
    let mut w = Writer::with_capacity(64);
    w.put_u64(7); // id
    w.put_u64(1); // n_tokens
    w.put_i64(5); // tokens[0]
    w.put_u64(1); // prompt_len
    w.put_i64(5); // last_token
    w.put_u8(9); // done: not 0/1
    assert_eq!(
        decode_never_panics(&w.into_frame()).err(),
        Some(SpillError::Malformed("done flag"))
    );

    // prompt_len exceeding the token history.
    let mut w = Writer::with_capacity(64);
    w.put_u64(7);
    w.put_u64(1);
    w.put_i64(5);
    w.put_u64(10); // prompt_len > n_tokens
    w.put_i64(5);
    w.put_u8(0);
    assert_eq!(
        decode_never_panics(&w.into_frame()).err(),
        Some(SpillError::Malformed("prompt_len exceeds token count"))
    );

    // Unknown mode tag.
    let mut w = Writer::with_capacity(64);
    w.put_u64(7);
    w.put_u64(1);
    w.put_i64(5);
    w.put_u64(1);
    w.put_i64(5);
    w.put_u8(0);
    w.put_u8(9); // mode tag: not 0/1/2
    assert_eq!(
        decode_never_panics(&w.into_frame()).err(),
        Some(SpillError::Malformed("mode tag"))
    );

    // A MiKV header whose policy/config region is random bytes: must land
    // on some structured error, whichever field trips first.
    let mut rng = Pcg32::new(0x51C4);
    let mut w = Writer::with_capacity(256);
    w.put_u64(7);
    w.put_u64(1);
    w.put_i64(5);
    w.put_u64(1);
    w.put_i64(5);
    w.put_u8(0);
    w.put_u8(0); // MiKV mode tag
    for _ in 0..128 {
        w.put_u8(rng.next_u32() as u8);
    }
    assert!(decode_never_panics(&w.into_frame()).is_err());
}
