//! Seeded chaos soak over the full serving stack (the fault-domain
//! hardening headline test).
//!
//! Boots the sharded TCP runtime with a deterministic [`FaultPlan`]
//! arming every recoverable fault domain at once — engine step errors,
//! worker panics (scheduler supervision + respawn), cold-tier IO faults
//! around the spill/restore path (with `session_ttl = 0` so every parked
//! session round-trips through disk), and stalled connection writers —
//! then drives a multi-turn load through it and asserts the contract the
//! hardening exists for:
//!
//! * **every turn reaches a terminal event** (`run_load` returning `Ok`
//!   means no client ever hung on a silent stream);
//! * **injected panics reconcile**: the server-reported `worker_restarts`
//!   delta equals the plan's fired count for `engine_step_panic` (plan
//!   clones share one occurrence sequence, so the test's handle sees
//!   exactly what the workers' handles fired);
//! * **nothing leaks**: the run leaves no cold-tier sessions or bytes
//!   behind.
//!
//! The schedule is occurrence-count based (see `util::faults`), so a
//! given plan injects faults at the same structural points every run —
//! which request absorbs each fault may vary with thread interleaving,
//! but the invariants above hold for every interleaving.

use mikv::coordinator::{CoordinatorConfig, QosConfig};
use mikv::model::StubEngine;
use mikv::server::loadgen::{run_load, with_stub_stack_full, LoadConfig};
use mikv::server::ServeConfig;
use mikv::util::faults::{FaultPlan, FaultRule, FaultSite};
use std::path::PathBuf;
use std::time::Duration;

/// Unique per-test cold-tier root under the OS temp dir.
fn tmp_cold_root(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("mikv-chaos-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

fn rule(every: u64, after: u64, limit: u64) -> FaultRule {
    FaultRule {
        every,
        after,
        limit,
        ms: 0,
    }
}

#[test]
fn seeded_chaos_soak_reaches_terminal_events_and_leaks_nothing() {
    let plan = FaultPlan::builder()
        .seed(0xC405)
        // Engine: recoverable step errors early, then two worker panics
        // spaced so the respawned worker takes real traffic too. The
        // panic thresholds stay well under the workload's guaranteed
        // decode-round count so both fire on every interleaving.
        .site(FaultSite::EngineStepError, rule(19, 4, 3))
        .site(FaultSite::EngineStepPanic, rule(15, 4, 2))
        // Cold tier: one failure at each crash point of the put sequence
        // and one read-back failure (session_ttl = 0 below forces every
        // parked session through the spill/restore path).
        .site(FaultSite::ColdPutBeforeWrite, rule(5, 0, 1))
        .site(FaultSite::ColdPutPartialWrite, rule(7, 0, 1))
        .site(FaultSite::ColdPutBeforeRename, rule(9, 0, 1))
        .site(FaultSite::ColdPutAfterRename, rule(11, 0, 1))
        .site(FaultSite::ColdTakeRead, rule(6, 0, 2))
        // TCP: brief writer stalls, often enough to hit several turns.
        .site(
            FaultSite::ConnStall,
            FaultRule {
                every: 13,
                after: 0,
                limit: 0,
                ms: 5,
            },
        )
        .build();

    let cold_root = tmp_cold_root("soak");
    let mut base = StubEngine::new(StubEngine::test_dims(256));
    base.faults = plan.clone();
    let coord_cfg = CoordinatorConfig {
        // Spill every parked session to disk immediately, so multi-turn
        // conversations exercise the cold path (and its faults) on every
        // turn boundary.
        session_ttl: Duration::ZERO,
        cold_dir: Some(cold_root.clone()),
        faults: plan.clone(),
        ..CoordinatorConfig::default()
    };
    let serve_cfg = ServeConfig {
        faults: plan.clone(),
        ..ServeConfig::default()
    };
    let cfg = LoadConfig {
        conns: 8,
        turns: 3,
        max_new: 12,
        seed: plan.seed(),
        ..LoadConfig::default()
    };
    let total = cfg.conns * cfg.turns;

    let load_cfg = cfg.clone();
    let report = with_stub_stack_full(2, coord_cfg, None, base, serve_cfg, move |addr| {
        run_load(&addr, &load_cfg)
    })
    .expect("stack boot")
    .expect("every connection must drive to completion (no hung streams)");

    // Every turn reached a terminal event: ok and error turns partition
    // the workload exactly.
    assert_eq!(
        report.turns_ok + report.turns_err,
        total,
        "turns must partition into ok ({}) + err ({})",
        report.turns_ok,
        report.turns_err
    );
    // The run made real progress despite the faults.
    assert!(
        report.turns_ok > 0,
        "chaos soak completed no turns at all ({} errors)",
        report.turns_err
    );
    // Supervision reconciliation: restarts seen on the wire equal panics
    // the shared plan actually fired — and the workload is sized so the
    // panic schedule is guaranteed to trigger at least once.
    assert_eq!(
        report.worker_restarts,
        plan.fired(FaultSite::EngineStepPanic),
        "worker_restarts must reconcile with injected panics"
    );
    assert!(
        report.worker_restarts >= 1,
        "the soak must actually exercise a worker respawn"
    );
    // No leaked cold state beyond "ghost" snapshots: a put that failed
    // *after* its rename and a failed take-read both leave a durable
    // file the owning registry no longer tracks, and a later respawn's
    // recovery scan may legitimately re-adopt it. Anything beyond that
    // budget is a real leak (a live conversation's session that nobody
    // consumed or released).
    let ghost_budget =
        plan.fired(FaultSite::ColdPutAfterRename) + plan.fired(FaultSite::ColdTakeRead);
    assert!(
        report.parked_cold_sessions as u64 <= ghost_budget,
        "cold sessions left behind ({}) exceed the re-adopted-ghost budget ({ghost_budget})",
        report.parked_cold_sessions
    );
    if report.parked_cold_sessions == 0 {
        assert_eq!(report.cold_bytes, 0, "cold bytes with no cold sessions");
    }
    // Loss accounting is bounded by what the workload could lose: at
    // most one parked session per connection per crash.
    assert!(
        report.sessions_lost <= (report.worker_restarts * cfg.conns as u64),
        "sessions_lost ({}) exceeds plausible bound",
        report.sessions_lost
    );
    let _ = std::fs::remove_dir_all(&cold_root);
}

/// Shed-aware backoff end to end: a QoS stack with a tiny backlog sheds
/// under a flash of concurrent turns, every rejection carries a
/// `retry_after_ms` hint, and the generator's retry ladder re-submits
/// instead of failing the turn. Whatever mix of shed/served the timing
/// produces, the invariants hold: terminal events partition the turns,
/// recovered turns never exceed attempted retries, and with retries on,
/// hint-less final failures cannot appear (every QoS rejection hints).
#[test]
fn qos_shed_retries_honor_retry_after_hints() {
    let qos = QosConfig {
        max_backlog: 1,
        retry_after_ms: 5,
        ..QosConfig::default()
    };
    let mut base = StubEngine::new(StubEngine::test_dims(256));
    base.decode_delay = Duration::from_micros(400);
    let cfg = LoadConfig {
        conns: 8,
        turns: 2,
        max_new: 10,
        max_retries: 4,
        ..LoadConfig::default()
    };
    let total = cfg.conns * cfg.turns;
    let load_cfg = cfg.clone();
    let report = with_stub_stack_full(
        1,
        CoordinatorConfig::default(),
        Some(qos),
        base,
        ServeConfig::default(),
        move |addr| run_load(&addr, &load_cfg),
    )
    .expect("stack boot")
    .expect("connections must drive to completion");

    assert_eq!(report.turns_ok + report.turns_err, total);
    assert!(
        report.retry_success <= report.retries,
        "recovered turns ({}) cannot exceed retries ({})",
        report.retry_success,
        report.retries
    );
    // A turn that still failed after the ladder carried a hint on its
    // final rejection (QoS sheds always hint) — so every error turn is
    // accounted as hinted.
    assert_eq!(
        report.rejects_with_hint, report.turns_err,
        "every final QoS rejection must carry retry_after_ms"
    );
}
