//! Integration tests against real artifacts (`make artifacts` first).
//!
//! Covers the full AOT bridge: golden parity (python-jit outputs replayed
//! bit-close through the rust-loaded executables), engine-level semantic
//! invariants (MiKV@100% == full cache), and the coordinator loop.

use mikv::coordinator::{
    CompressionSpec, Coordinator, CoordinatorConfig, Op, Priority, Request, Response,
    ServeEvent,
};
use mikv::eval::corpus;
use mikv::model::{CacheMode, Engine, Session};
use mikv::quant::Precision;
use mikv::runtime::client::HostInput;
use mikv::runtime::{Manifest, Weights};
use mikv::util::rng::Pcg32;
use std::sync::mpsc;
use std::time::Instant;

const ARTIFACTS: &str = env!("CARGO_MANIFEST_DIR");

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(ARTIFACTS).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

fn close(a: &[f32], b: &[f32], atol: f32, rtol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "{what}[{i}]: {x} vs {y} (tol {tol})"
        );
    }
}

/// Replay the golden fixtures through the rust-loaded executables.
#[test]
fn golden_parity_all_graphs() {
    require_artifacts!();
    let manifest = Manifest::load(artifacts_dir()).unwrap();
    let entry = manifest.model("cfg-tiny").unwrap();
    let rt = mikv::runtime::Runtime::new().unwrap();

    let weights = Weights::load(manifest.path(&entry.weights_file)).unwrap();
    for (&batch, gfile) in &entry.goldens {
        let golden = Weights::load(manifest.path(gfile)).unwrap();
        for kind in ["prefill", "decode_mikv", "decode_full"] {
            let g = entry.graph(kind, batch).unwrap();
            let exe = rt.load_executable(&manifest.path(&g.file), g.clone()).unwrap();

            // Assemble inputs: weights then the golden "in.*" tensors in
            // manifest order.
            let n_w = entry.param_order.len();
            let mut bufs = Vec::new();
            for (i, spec) in g.inputs.iter().enumerate() {
                let host_f32;
                let host_i64;
                let input = if i < n_w {
                    let t = weights.get_f32(&entry.param_order[i]).unwrap();
                    host_f32 = t.data().to_vec();
                    HostInput::F32(&host_f32)
                } else {
                    let name = format!("{kind}.in.{}", spec.name);
                    match golden.get(&name) {
                        Some(mikv::runtime::weights::AnyTensor::F32(t)) => {
                            host_f32 = t.data().to_vec();
                            HostInput::F32(&host_f32)
                        }
                        Some(mikv::runtime::weights::AnyTensor::I64(t)) => {
                            host_i64 = t.data().to_vec();
                            HostInput::I64(&host_i64)
                        }
                        None => panic!("golden tensor {name} missing"),
                    }
                };
                bufs.push(rt.upload(spec, &input).unwrap());
            }
            let args: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
            let outs = exe.execute(&args).unwrap();

            for out_name in &g.outputs {
                let got = exe.output_f32(&outs, out_name).unwrap();
                let want = golden
                    .get_f32(&format!("{kind}.out.{out_name}"))
                    .unwrap();
                close(
                    &got,
                    want.data(),
                    2e-4,
                    2e-3,
                    &format!("{kind}-b{batch}.{out_name}"),
                );
            }
        }
    }
}

/// MiKV with importance ratio 1.0 (everything hi, FP16) must generate the
/// same tokens as the exact full cache.
#[test]
fn mikv_full_ratio_matches_full_cache() {
    require_artifacts!();
    let engine = Engine::load(artifacts_dir(), "cfg-tiny").unwrap();
    let dims = engine.dims().clone();
    let mut rng = Pcg32::new(42);
    for trial in 0..3 {
        let prompt: Vec<i64> = (0..20)
            .map(|_| 1 + rng.gen_below(dims.vocab as u32 - 1) as i64)
            .collect();

        let mut full = Session::new(0, &dims, CacheMode::Full).unwrap();
        let out_full = engine.generate_greedy(&mut full, &prompt, 8, None).unwrap();

        let mut cfg = mikv::kvcache::CacheConfig::full(
            dims.n_layers,
            dims.n_kv_heads,
            dims.d_head,
            dims.max_seq,
        );
        cfg.importance_ratio = 1.0;
        let mut mikv = Session::new(
            1,
            &dims,
            CacheMode::Mikv {
                cfg,
                policy: "h2o".into(),
            },
        )
        .unwrap();
        let out_mikv = engine.generate_greedy(&mut mikv, &prompt, 8, None).unwrap();
        assert_eq!(out_full, out_mikv, "trial {trial}");
        assert!((mikv.cache.cache_size_pct() - 100.0).abs() < 1e-9);
    }
}

/// Oracle with k >= S+1 must equal the full cache exactly.
#[test]
fn oracle_full_k_matches_full_cache() {
    require_artifacts!();
    let engine = Engine::load(artifacts_dir(), "cfg-tiny").unwrap();
    let dims = engine.dims().clone();
    let prompt: Vec<i64> = (1..=16).collect();

    let mut full = Session::new(0, &dims, CacheMode::Full).unwrap();
    let a = engine.generate_greedy(&mut full, &prompt, 6, None).unwrap();
    let mut oracle = Session::new(
        1,
        &dims,
        CacheMode::Oracle {
            k: dims.max_seq + 1,
        },
    )
    .unwrap();
    let b = engine.generate_greedy(&mut oracle, &prompt, 6, None).unwrap();
    assert_eq!(a, b);
}

/// Batched decode (b=2 graph) must agree with two b=1 decodes.
#[test]
fn batched_decode_matches_single() {
    require_artifacts!();
    let engine = Engine::load(artifacts_dir(), "cfg-tiny").unwrap();
    let dims = engine.dims().clone();
    let mut rng = Pcg32::new(7);
    let prompts: Vec<Vec<i64>> = (0..2)
        .map(|_| {
            (0..10 + rng.gen_below(8) as usize)
                .map(|_| 1 + rng.gen_below(dims.vocab as u32 - 1) as i64)
                .collect()
        })
        .collect();

    // singles
    let mut singles = Vec::new();
    for p in &prompts {
        let mut s = Session::new(0, &dims, CacheMode::mikv(&dims, 0.5, Precision::Int4)).unwrap();
        singles.push(engine.generate_greedy(&mut s, p, 5, None).unwrap());
    }

    // batched: prefill both, then decode as a pair every step
    let mut s0 = Session::new(10, &dims, CacheMode::mikv(&dims, 0.5, Precision::Int4)).unwrap();
    let mut s1 = Session::new(11, &dims, CacheMode::mikv(&dims, 0.5, Precision::Int4)).unwrap();
    {
        let mut group = [&mut s0, &mut s1];
        engine.prefill(&mut group, &prompts).unwrap();
    }
    for _ in 1..5 {
        let mut group = [&mut s0, &mut s1];
        let rows = engine.decode_step(&mut group).unwrap();
        for (sess, row) in group.iter_mut().zip(rows) {
            let tok = mikv::model::sampler::greedy(&row);
            sess.last_token = tok;
            sess.tokens.push(tok);
        }
    }
    assert_eq!(s0.generated(), &singles[0][..]);
    assert_eq!(s1.generated(), &singles[1][..]);
}

/// The coordinator serves concurrent mixed-mode requests to completion,
/// with compression specs resolved at admission.
#[test]
fn coordinator_serves_mixed_requests() {
    require_artifacts!();
    let engine = Engine::load(artifacts_dir(), "cfg-tiny").unwrap();
    let dims = engine.dims().clone();
    let (tx, rx) = mpsc::channel::<Op>();
    let (reply_tx, reply_rx) = mpsc::channel::<ServeEvent>();

    let specs = [
        CompressionSpec::full(),
        CompressionSpec::mikv(0.3, "int2"),
        CompressionSpec::h2o(0.3),
        CompressionSpec::oracle(8),
        CompressionSpec::rtn("int8"),
    ];
    let mut rng = Pcg32::new(3);
    for (i, spec) in specs.iter().enumerate() {
        let prompt: Vec<i64> = (0..12)
            .map(|_| 1 + rng.gen_below(dims.vocab as u32 - 1) as i64)
            .collect();
        tx.send(Op::Submit(Request {
            id: i as u64,
            prompt,
            max_new: 4,
            stop: None,
            spec: spec.clone(),
            session: None,
            keep: false,
            tenant: 0,
            priority: Priority::Interactive,
            submitted_at: Instant::now(),
            reply: Box::new(reply_tx.clone()),
        }))
        .unwrap();
    }
    drop(tx);
    drop(reply_tx);

    Coordinator::new(engine, CoordinatorConfig::default()).run(rx);

    let mut responses: Vec<Response> = reply_rx
        .iter()
        .filter_map(|e| match e {
            ServeEvent::Done(r) => Some(r),
            _ => None,
        })
        .collect();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), specs.len());
    for r in &responses {
        assert!(r.error.is_none(), "req {} failed: {:?}", r.id, r.error);
        assert_eq!(r.tokens.len(), 4);
        assert!(r.metrics.ttft <= r.metrics.latency);
        assert!(r.metrics.cache_pct > 0.0);
        assert!(r.metrics.hi_slots + r.metrics.lo_slots > 0);
    }
}

/// Manifest corpus constants must match the rust corpus module.
#[test]
fn corpus_constants_cross_check() {
    require_artifacts!();
    let manifest = Manifest::load(artifacts_dir()).unwrap();
    corpus::check_manifest_constants(&manifest.corpus).unwrap();
}

/// The bulk quantization graph must match the rust-native quantizer.
#[test]
fn quant_graph_matches_native() {
    require_artifacts!();
    let manifest = Manifest::load(artifacts_dir()).unwrap();
    let entry = manifest.model("cfg-tiny").unwrap();
    let rt = mikv::runtime::Runtime::new().unwrap();
    let dims = &entry.dims;
    let (rows, dim, group) = (dims.max_seq, dims.d_head, dims.quant_group);

    for (&bits, file) in &entry.quant_graphs {
        let prec = match bits {
            2 => Precision::Int2,
            3 => Precision::Int3,
            4 => Precision::Int4,
            8 => Precision::Int8,
            _ => continue,
        };
        // quant graphs take one [rows, dim] f32 input, return 3 outputs
        let g = mikv::runtime::GraphEntry {
            file: file.clone(),
            batch: 1,
            inputs: vec![mikv::runtime::TensorSpec {
                name: "x".into(),
                dtype: mikv::runtime::artifacts::Dtype::F32,
                shape: vec![rows, dim],
            }],
            outputs: vec!["codes".into(), "scales".into(), "zeros".into()],
        };
        let exe = rt.load_executable(&manifest.path(file), g).unwrap();

        let mut rng = Pcg32::new(bits as u64);
        let x: Vec<f32> = (0..rows * dim).map(|_| rng.gen_normal() * 2.0).collect();
        let buf = rt.upload_f32(&x, &[rows, dim]).unwrap();
        let outs = exe.execute(&[&buf]).unwrap();
        let codes = outs[0].to_vec::<f32>().unwrap();
        let scales = outs[1].to_vec::<f32>().unwrap();
        let zeros = outs[2].to_vec::<f32>().unwrap();

        // native per-token quantization must agree
        let prm = mikv::quant::QuantParams::new(prec, group);
        let ngroups = dim / group;
        for r in 0..rows {
            let q = mikv::quant::quantize(&x[r * dim..(r + 1) * dim], prm);
            for c in 0..dim {
                assert_eq!(
                    q.codes[c] as f32,
                    codes[r * dim + c],
                    "bits={bits} row={r} ch={c}"
                );
            }
            for gi in 0..ngroups {
                let idx = r * ngroups + gi;
                assert!((q.scales[gi] - scales[idx]).abs() < 1e-6, "scale r={r}");
                assert!((q.zeros[gi] - zeros[idx]).abs() < 1e-6, "zero r={r}");
            }
        }
    }
}

/// Full TCP round trip (legacy one-shot shape): server + coordinator +
/// client over a real socket.
#[test]
fn tcp_server_round_trip() {
    require_artifacts!();
    let engine = Engine::load(artifacts_dir(), "cfg-tiny").unwrap();
    let (tx, rx) = mpsc::channel::<Op>();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let _ = mikv::server::serve(listener, tx);
    });

    // client on a worker thread; coordinator (engine, not Send) on ours
    let client = std::thread::spawn(move || -> anyhow::Result<Vec<(u64, usize, f64)>> {
        let mut c = mikv::server::Client::connect(&addr)?;
        let ids = [
            c.request(&[1, 5, 9, 13], 3, &CompressionSpec::full())?,
            c.request(&[2, 6, 10], 3, &CompressionSpec::mikv(0.3, "int4"))?,
            c.request(&[3, 7], 2, &CompressionSpec::h2o(0.5))?,
        ];
        let mut out = Vec::new();
        for _ in &ids {
            let v = c.recv()?;
            anyhow::ensure!(v.field("error")? == &mikv::util::json::Json::Null);
            out.push((
                v.field_i64("id")? as u64,
                v.field_arr("tokens")?.len(),
                v.field_f64("cache_pct")?,
            ));
        }
        // bad request must produce an error response, not kill the server
        c.send_line("{not json")?;
        let v = c.recv()?;
        anyhow::ensure!(v.field("error")? != &mikv::util::json::Json::Null);
        Ok(out)
    });

    // Run the coordinator until the client is done: poll the join handle
    // from a watcher that closes the channel path by dropping... simplest:
    // run in a loop with a deadline on a helper channel.
    let coord_engine = engine;
    let handle = std::thread::spawn(move || client.join().unwrap());
    Coordinator::new(coord_engine, CoordinatorConfig::default()).run_until(rx, || {
        handle.is_finished()
    });
    let results = handle.join().unwrap().unwrap();
    assert_eq!(results.len(), 3);
    for (id, n_tokens, cache_pct) in results {
        assert!(id >= 1 && id <= 3);
        assert!(n_tokens >= 2);
        assert!(cache_pct > 0.0);
    }
}

/// Error paths: oversized and empty prompts are rejected cleanly.
#[test]
fn engine_rejects_bad_prompts() {
    require_artifacts!();
    let engine = Engine::load(artifacts_dir(), "cfg-tiny").unwrap();
    let dims = engine.dims().clone();
    let too_long = vec![1i64; dims.max_seq + 1];
    assert!(engine.prefill_raw(&[too_long]).is_err());
    assert!(engine.prefill_raw(&[vec![]]).is_err());
}
