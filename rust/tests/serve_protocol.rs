//! Serving API v1 integration tests: the full stack — coordinator, session
//! registry, TCP server, wire protocol — driven over real sockets with the
//! artifact-free deterministic [`StubEngine`]. These run everywhere (no
//! `make artifacts` needed) and lock the acceptance behaviour:
//!
//! * a 2-turn `generate` → `append` conversation reuses the same cache
//!   (hi/lo tier occupancy carries over, host bytes reported per turn);
//! * streamed `token` events arrive before the terminal `done` and match
//!   its token list;
//! * `cancel` interrupts in-flight generation; `stats` answers over the
//!   wire; structured error codes and the legacy one-shot shape hold.

use mikv::coordinator::{CompressionSpec, Coordinator, CoordinatorConfig, Op};
use mikv::model::StubEngine;
use mikv::server::{serve, Client, RequestBuilder};
use mikv::util::json::Json;
use std::sync::mpsc;
use std::time::Duration;

/// Boot engine + coordinator + TCP server, run `client` against it on a
/// worker thread, and drain the stack when the client finishes.
fn run_stack(
    engine: StubEngine,
    cfg: CoordinatorConfig,
    client: impl FnOnce(String) -> anyhow::Result<()> + Send + 'static,
) {
    let (tx, rx) = mpsc::channel::<Op>();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let _ = serve(listener, tx);
    });
    let handle = std::thread::spawn(move || client(addr));
    Coordinator::new(engine, cfg).run_until(rx, || handle.is_finished());
    handle.join().unwrap().unwrap();
}

/// The acceptance path: a kept streamed `generate` then an `append` over
/// the same socket reuse ONE `CacheManager` — occupancy carries over and
/// grows turn over turn, with per-turn host bytes on each `done`.
#[test]
fn two_turn_conversation_reuses_cache_over_tcp() {
    let engine = StubEngine::new(StubEngine::test_dims(64));
    run_stack(engine, CoordinatorConfig::default(), |addr| {
        let mut c = Client::connect(&addr)?;

        // --- Turn 1: streamed generate, keep the session ---
        let id1 = c.next_id();
        c.submit(
            &RequestBuilder::generate(id1)
                .prompt(&[1, 2, 3])
                .max_new(4)
                .keep(true)
                .compression(CompressionSpec::mikv(0.5, "int4")),
        )?;
        let (streamed, done) = c.read_turn(id1)?;
        anyhow::ensure!(done.field_str("event")? == "done", "turn 1: {done}");
        let final_tokens: Vec<i64> = done
            .field_arr("tokens")?
            .iter()
            .filter_map(Json::as_i64)
            .collect();
        anyhow::ensure!(
            streamed == final_tokens,
            "token events {streamed:?} != done tokens {final_tokens:?}"
        );
        anyhow::ensure!(streamed.len() == 4, "max_new honoured");
        let sid = done.field_i64("session")?;
        let occ1 = done.field_i64("hi_slots")? + done.field_i64("lo_slots")?;
        // prompt 3 + 3 decoded KV appends = 6 slots × 4 planes
        anyhow::ensure!(occ1 == 24, "turn 1 occupancy {occ1}");
        let bytes1 = done.field_i64("host_bytes")?;
        anyhow::ensure!(bytes1 > 0, "turn 1 must report host bytes");

        // --- Turn 2: append continues the SAME cache ---
        let id2 = c.next_id();
        c.submit(
            &RequestBuilder::append(id2, sid as u64)
                .prompt(&[4, 5])
                .max_new(3),
        )?;
        let (streamed2, done2) = c.read_turn(id2)?;
        anyhow::ensure!(done2.field_str("event")? == "done", "turn 2: {done2}");
        anyhow::ensure!(
            done2.field_i64("session")? == sid,
            "session id is stable across turns"
        );
        anyhow::ensure!(
            done2.field_i64("prompt_tokens")? == 2,
            "per-turn prompt size"
        );
        anyhow::ensure!(streamed2.len() == 3);
        let occ2 = done2.field_i64("hi_slots")? + done2.field_i64("lo_slots")?;
        // turn 1's 6 slots + fed last token + 2 appended prompt tokens
        // + 2 decoded KV appends = 11 slots × 4 planes: the hi/lo tiers
        // carried over — nothing was re-prefilled.
        anyhow::ensure!(occ2 == 44, "occupancy must carry over: {occ2}");
        anyhow::ensure!(
            done2.field_i64("host_bytes")? >= bytes1,
            "turn 2 reports its own (grown) footprint"
        );

        // --- Stats over the wire: the session is parked again ---
        let id3 = c.next_id();
        c.submit(&RequestBuilder::stats(id3))?;
        let (_, stats) = c.read_turn(id3)?;
        anyhow::ensure!(stats.field_str("event")? == "stats", "{stats}");
        anyhow::ensure!(stats.field_i64("completed")? == 2);
        anyhow::ensure!(stats.field_i64("parked_sessions")? == 1);
        anyhow::ensure!(stats.field_i64("parked_bytes")? > 0);
        Ok(())
    });
}

/// `cancel` interrupts an in-flight streamed generation: the target's
/// terminal `done` carries `cancelled: true` with the partial tokens, and
/// the cancel op is answered with `found: true`.
#[test]
fn cancel_interrupts_inflight_generation_over_tcp() {
    let mut engine = StubEngine::new(StubEngine::test_dims(512));
    // Throttle decode so the cancel deterministically lands mid-flight
    // (the budget below would otherwise take ~2.5 s to exhaust).
    engine.decode_delay = Duration::from_millis(5);
    run_stack(engine, CoordinatorConfig::default(), |addr| {
        let mut c = Client::connect(&addr)?;
        let id1 = c.next_id();
        c.submit(
            &RequestBuilder::generate(id1)
                .prompt(&[1, 2, 3])
                .max_new(100_000)
                .compression(CompressionSpec::mikv(0.25, "int4")),
        )?;
        // The first streamed token proves the turn is in flight.
        let first = c.recv()?;
        anyhow::ensure!(
            first.field_str("event")? == "token",
            "expected a token event first, got {first}"
        );

        let id2 = c.next_id();
        c.submit(&RequestBuilder::cancel(id2, id1))?;
        // Terminal events can interleave with remaining token events.
        let mut done: Option<Json> = None;
        let mut cres: Option<Json> = None;
        while done.is_none() || cres.is_none() {
            let v = c.recv()?;
            let vid = v.field("id").ok().and_then(Json::as_i64);
            let ev = v.field_str("event").unwrap_or("").to_string();
            match (vid, ev.as_str()) {
                (Some(i), "done") if i == id1 as i64 => done = Some(v),
                (Some(i), "token") if i == id1 as i64 => {}
                (Some(i), "cancelled") if i == id2 as i64 => cres = Some(v),
                _ => anyhow::bail!("unexpected line: {v}"),
            }
        }
        let done = done.expect("set by loop");
        let cres = cres.expect("set by loop");
        anyhow::ensure!(
            cres.field("found")? == &Json::Bool(true),
            "cancel must find the in-flight turn: {cres}"
        );
        anyhow::ensure!(
            done.field("cancelled")? == &Json::Bool(true),
            "terminal done must be marked cancelled: {done}"
        );
        let partial = done.field_arr("tokens")?.len();
        anyhow::ensure!(
            partial >= 1 && partial < 100_000,
            "partial tokens delivered, got {partial}"
        );
        Ok(())
    });
}

/// The legacy v-less one-shot wire shape is locked: single response line,
/// exact field set, no event framing — and malformed input (including the
/// once silently-coerced non-integer prompt token) answers in the same
/// legacy shape.
#[test]
fn legacy_one_shot_wire_shape_is_locked() {
    let engine = StubEngine::new(StubEngine::test_dims(32));
    run_stack(engine, CoordinatorConfig::default(), |addr| {
        let mut c = Client::connect(&addr)?;
        let id = c.request(&[1, 2, 3], 3, &CompressionSpec::full())?;
        let v = c.recv()?;
        let keys: Vec<&str> = v
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("not an object: {v}"))?
            .iter()
            .map(|(k, _)| k)
            .collect();
        anyhow::ensure!(
            keys == vec![
                "id",
                "tokens",
                "ttft_ms",
                "latency_ms",
                "prompt_tokens",
                "generated_tokens",
                "cache_pct",
                "host_bytes",
                "error"
            ],
            "legacy shape drifted: {keys:?}"
        );
        anyhow::ensure!(v.field_i64("id")? == id as i64);
        anyhow::ensure!(v.field("error")? == &Json::Null);
        anyhow::ensure!(v.field_arr("tokens")?.len() == 3);
        anyhow::ensure!(v.field_f64("cache_pct")? > 0.0);

        // Garbage stays answered in the legacy shape, not as an event.
        c.send_line("{not json")?;
        let v = c.recv()?;
        anyhow::ensure!(v.field("event").is_err(), "must not be an event: {v}");
        anyhow::ensure!(v.field("error")? != &Json::Null);

        // The old `unwrap_or(0)` prompt coercion is rejected outright.
        c.send_line(r#"{"id":5,"prompt":[1,"x"],"max_new":2}"#)?;
        let v = c.recv()?;
        anyhow::ensure!(
            v.field_str("error")?.contains("not an integer"),
            "got {v}"
        );
        anyhow::ensure!(v.field_i64("id")? == 5);
        Ok(())
    });
}

/// Structured v1 error codes: bad specs, unknown sessions, parse failures
/// and capacity overflows each map onto their stable code — and a
/// rejected `append` leaves the parked session intact.
#[test]
fn v1_errors_carry_structured_codes() {
    let engine = StubEngine::new(StubEngine::test_dims(16));
    run_stack(engine, CoordinatorConfig::default(), |addr| {
        let mut c = Client::connect(&addr)?;

        // Unknown mode → bad_request at admission (parse stays lenient).
        let id = c.next_id();
        let warp = CompressionSpec {
            mode: "warp".to_string(),
            ..CompressionSpec::full()
        };
        c.submit(&RequestBuilder::generate(id).prompt(&[1]).compression(warp))?;
        let (toks, term) = c.read_turn(id)?;
        anyhow::ensure!(toks.is_empty());
        anyhow::ensure!(term.field_str("event")? == "error", "{term}");
        anyhow::ensure!(term.field_str("code")? == "bad_request");

        // Append to a session that was never kept.
        let id = c.next_id();
        c.submit(&RequestBuilder::append(id, 9999).prompt(&[1]))?;
        let (_, term) = c.read_turn(id)?;
        anyhow::ensure!(term.field_str("code")? == "session_not_found", "{term}");

        // v1 parse failures event-encode with bad_request.
        c.send_line(r#"{"v":1,"op":"generate","id":77,"prompt":[1,2.5]}"#)?;
        let v = c.recv()?;
        anyhow::ensure!(v.field_str("event")? == "error", "{v}");
        anyhow::ensure!(v.field_str("code")? == "bad_request");
        anyhow::ensure!(v.field_i64("id")? == 77);
        c.send_line(r#"{"v":1,"op":"warp","id":78}"#)?;
        let v = c.recv()?;
        anyhow::ensure!(v.field_str("code")? == "bad_request", "{v}");

        // Capacity: a kept 10-token session (max_seq 16) cannot absorb a
        // 10-token append → cache_full, but the session survives...
        let id = c.next_id();
        c.submit(
            &RequestBuilder::generate(id)
                .prompt(&[1; 10])
                .max_new(1)
                .keep(true),
        )?;
        let (_, done) = c.read_turn(id)?;
        anyhow::ensure!(done.field_str("event")? == "done", "{done}");
        let sid = done.field_i64("session")? as u64;
        let id = c.next_id();
        c.submit(&RequestBuilder::append(id, sid).prompt(&[1; 10]).max_new(1))?;
        let (_, term) = c.read_turn(id)?;
        anyhow::ensure!(term.field_str("code")? == "cache_full", "{term}");
        // ...and a smaller append still succeeds against the same session.
        let id = c.next_id();
        c.submit(&RequestBuilder::append(id, sid).prompt(&[2, 3]).max_new(1))?;
        let (_, done) = c.read_turn(id)?;
        anyhow::ensure!(done.field_str("event")? == "done", "{done}");
        anyhow::ensure!(done.field_i64("session")? == sid as i64);
        Ok(())
    });
}
