//! Deterministic concurrency suite for the sharded serving runtime.
//!
//! Drives the full stack — scheduler, 4 engine workers, TCP wire protocol
//! — with multiple simultaneous client connections interleaving
//! generate/append/cancel on the deterministic `StubEngine`. Locks the
//! sharding contract:
//!
//! * **no session leaks** — after every conversation releases its session
//!   (final turn without `keep`, or TTL sweep), the parked registries and
//!   the buffer pools return to baseline (0 parked bytes, 0 outstanding
//!   blocks);
//! * **append-after-park affinity** — a follow-up `append` always finds
//!   the worker holding that session's parked cache (occupancy carries
//!   over turn after turn for every session, across all 4 workers);
//! * **stream isolation** — concurrent in-flight turns on one connection
//!   interleave at the line level, but each request's token stream stays
//!   contiguous, in order, and exactly matches its terminal `done`.
//!
//! Everything is event-synchronized (blocking reads on real sockets) with
//! seeded RNG only — no sleeps-as-synchronization. The stub's
//! `decode_delay` is used solely as a *throttle* (it bounds how fast an
//! in-flight turn can finish) so that cancel/placement races are resolved
//! by protocol events, never by timing guesses.

use mikv::coordinator::{CompressionSpec, CoordinatorConfig, Priority, QosConfig};
use mikv::model::StubEngine;
use mikv::server::loadgen::{with_stub_stack, with_stub_stack_qos};
use mikv::server::{Client, RequestBuilder};
use mikv::util::json::Json;
use mikv::util::rng::Pcg32;
use std::collections::HashMap;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

const VOCAB: i64 = 32; // StubEngine::test_dims vocab

/// Boot a sharded stub stack and run `body` against its address; the
/// runtime drains when `body` returns (assertion panics propagate).
fn on_stack(
    workers: usize,
    max_seq: usize,
    cfg: CoordinatorConfig,
    delay: Duration,
    body: impl FnOnce(String) + Send + 'static,
) {
    let mut base = StubEngine::new(StubEngine::test_dims(max_seq));
    base.decode_delay = delay;
    with_stub_stack(workers, cfg, base, body).expect("stack boot");
}

/// [`on_stack`] with the QoS admission layer enabled.
fn on_stack_qos(
    workers: usize,
    max_seq: usize,
    qos: QosConfig,
    delay: Duration,
    body: impl FnOnce(String) + Send + 'static,
) {
    let mut base = StubEngine::new(StubEngine::test_dims(max_seq));
    base.decode_delay = delay;
    with_stub_stack_qos(
        workers,
        CoordinatorConfig::default(),
        Some(qos),
        base,
        body,
    )
    .expect("stack boot");
}

/// Fetch a merged stats snapshot over the wire.
fn stats(addr: &str) -> Json {
    let mut c = Client::connect(addr).unwrap();
    let id = c.next_id();
    c.submit(&RequestBuilder::stats(id)).unwrap();
    let (_, v) = c.read_turn(id).unwrap();
    assert_eq!(v.field_str("event").unwrap(), "stats", "{v}");
    v
}

/// The deterministic stub token rule: prefill token is the prompt sum mod
/// vocab, every decode token is predecessor + 1 mod vocab.
fn expect_generate_tokens(prompt: &[i64], n: usize) -> Vec<i64> {
    let mut toks = Vec::with_capacity(n);
    let mut t = prompt.iter().sum::<i64>().rem_euclid(VOCAB);
    for _ in 0..n {
        toks.push(t);
        t = (t + 1).rem_euclid(VOCAB);
    }
    toks
}

/// The soak: 6 concurrent connections × 3-turn conversations against 4
/// workers. Asserts per-turn determinism, cross-turn cache carry-over
/// (affinity), and a leak-free end state.
#[test]
fn concurrent_conversations_over_four_workers_leave_no_leaks() {
    on_stack(4, 128, CoordinatorConfig::default(), Duration::ZERO, run_soak);
}

fn run_soak(stack_addr: String) {
    let conns = 6usize;
    let turns = 3usize;
    let mut drivers = Vec::new();
    for conn in 0..conns {
        let addr = stack_addr.clone();
        drivers.push(std::thread::spawn(move || {
            let mut rng = Pcg32::new(0xC0C0 ^ ((conn as u64 + 1) << 8));
            let mut client = Client::connect(&addr).unwrap();
            let mut session: Option<u64> = None;
            let mut last_occ = 0i64;
            for turn in 0..turns {
                let id = client.next_id();
                let keep = turn + 1 < turns; // final turn releases the session
                let prompt: Vec<i64> = (0..(2 + rng.gen_below(4) as usize))
                    .map(|_| rng.gen_range(1, VOCAB - 1))
                    .collect();
                let max_new = 2 + rng.gen_below(4) as usize;
                let builder = match session {
                    Some(sid) => RequestBuilder::append(id, sid)
                        .prompt(&prompt)
                        .max_new(max_new)
                        .keep(keep),
                    None => RequestBuilder::generate(id)
                        .prompt(&prompt)
                        .max_new(max_new)
                        .keep(keep)
                        .compression(CompressionSpec::mikv(0.5, "int4")),
                };
                client.submit(&builder).unwrap();
                let (streamed, done) = client.read_turn(id).unwrap();
                assert_eq!(done.field_str("event").unwrap(), "done", "{done}");
                let final_tokens: Vec<i64> = done
                    .field_arr("tokens")
                    .unwrap()
                    .iter()
                    .filter_map(Json::as_i64)
                    .collect();
                assert_eq!(streamed, final_tokens, "stream == done tokens");
                assert_eq!(streamed.len(), max_new, "budget honoured");
                if turn == 0 {
                    // Exact deterministic content, independent of which
                    // worker (and which tensor seed) served the turn.
                    assert_eq!(streamed, expect_generate_tokens(&prompt, max_new));
                }
                let occ = done.field_i64("hi_slots").unwrap()
                    + done.field_i64("lo_slots").unwrap();
                assert!(
                    occ > last_occ,
                    "occupancy carries across turns: {last_occ} -> {occ}"
                );
                last_occ = occ;
                match done.field("session") {
                    Ok(s) if keep => {
                        let sid = s.as_i64().unwrap() as u64;
                        if let Some(prev) = session {
                            assert_eq!(prev, sid, "session id stable");
                        }
                        session = Some(sid);
                    }
                    _ => {
                        assert!(!keep, "kept turn must return a session id");
                        session = None;
                    }
                }
            }
        }));
    }
    for d in drivers {
        d.join().expect("client connection failed");
    }

    // End state: every conversation released its session → nothing parked,
    // every pooled shadow block returned, all turns accounted for.
    let v = stats(&stack_addr);
    assert_eq!(v.field_i64("completed").unwrap(), (conns * turns) as i64);
    assert_eq!(v.field_i64("parked_sessions").unwrap(), 0, "session leak");
    assert_eq!(v.field_i64("parked_bytes").unwrap(), 0, "parked bytes leak");
    assert_eq!(
        v.field_i64("pool_outstanding_blocks").unwrap(),
        0,
        "pooled blocks leak"
    );
    assert_eq!(v.field_i64("active").unwrap(), 0);
    assert_eq!(v.field_i64("waiting").unwrap(), 0);
    // per-worker rows are present and consistent with the aggregate
    let rows = v.field_arr("workers").unwrap();
    assert_eq!(rows.len(), 4);
    let sum: i64 = rows
        .iter()
        .map(|r| r.field_i64("completed").unwrap())
        .sum();
    assert_eq!(sum, (conns * turns) as i64);
}

/// Eight sessions created concurrently spread across all 4 workers
/// (deterministic least-loaded placement), and every `append` lands on the
/// worker that parked the session — across workers, proven by the session
/// id arithmetic, the per-worker parked counts, and the cache carry-over.
#[test]
fn appends_land_on_the_owning_worker_across_all_workers() {
    // The 2 ms per-session decode cost is a throttle: 8 concurrent turns
    // each need >= 3 decode steps, so all 8 are still in flight while the
    // scheduler places them (placement sees the true in-flight loads).
    on_stack(
        4,
        128,
        CoordinatorConfig::default(),
        Duration::from_millis(2),
        run_affinity,
    );
}

fn run_affinity(stack_addr: String) {
    let sessions = 8usize;
    let mut client = Client::connect(&stack_addr).unwrap();

    // Submit all generates before reading any reply → concurrent in
    // flight, placement = least-loaded with lowest-index ties: 2 each.
    let mut ids = Vec::new();
    for s in 0..sessions {
        let id = client.next_id();
        ids.push(id);
        client
            .submit(
                &RequestBuilder::generate(id)
                    .prompt(&[1 + s as i64, 2, 3])
                    .max_new(4)
                    .keep(true)
                    .compression(CompressionSpec::mikv(0.5, "int4")),
            )
            .unwrap();
    }
    // Collect every turn's done (token events interleave across ids; each
    // id's stream must stay contiguous).
    let mut streams: HashMap<i64, Vec<i64>> = HashMap::new();
    let mut dones: HashMap<i64, Json> = HashMap::new();
    while dones.len() < sessions {
        let v = client.recv().unwrap();
        let id = v.field_i64("id").unwrap();
        match v.field_str("event").unwrap() {
            "token" => {
                let stream = streams.entry(id).or_default();
                assert_eq!(
                    v.field_i64("i").unwrap(),
                    stream.len() as i64,
                    "indices contiguous per turn"
                );
                stream.push(v.field_i64("t").unwrap());
            }
            "done" => {
                dones.insert(id, v);
            }
            other => panic!("unexpected event {other}: {v}"),
        }
    }

    let mut owners: HashMap<usize, usize> = HashMap::new();
    let mut sids = Vec::new();
    for id in &ids {
        let done = &dones[&(*id as i64)];
        let tokens: Vec<i64> = done
            .field_arr("tokens")
            .unwrap()
            .iter()
            .filter_map(Json::as_i64)
            .collect();
        assert_eq!(&streams[&(*id as i64)], &tokens, "stream == done");
        let sid = done.field_i64("session").unwrap() as u64;
        sids.push(sid);
        *owners
            .entry(mikv::coordinator::worker_of_session(sid, 4))
            .or_default() += 1;
    }
    let mut unique = sids.clone();
    unique.sort_unstable();
    unique.dedup();
    assert_eq!(unique.len(), sessions, "distinct session ids: {sids:?}");
    assert_eq!(owners.len(), 4, "all 4 workers own sessions: {owners:?}");
    for (&w, &n) in &owners {
        assert_eq!(n, 2, "worker {w} owns {n} sessions (want 2): {owners:?}");
    }
    // Per-worker parked counts agree with the id arithmetic.
    let v = stats(&stack_addr);
    assert_eq!(v.field_i64("parked_sessions").unwrap(), sessions as i64);
    for row in v.field_arr("workers").unwrap() {
        assert_eq!(
            row.field_i64("parked_sessions").unwrap(),
            2,
            "parked spread: {v}"
        );
    }

    // Append to every session: must find the parked cache on its owning
    // worker (a misroute would answer session_not_found) and grow it.
    for (id, sid) in ids.iter().zip(&sids) {
        let done = &dones[&(*id as i64)];
        let occ1 = done.field_i64("hi_slots").unwrap() + done.field_i64("lo_slots").unwrap();
        let aid = client.next_id();
        client
            .submit(
                &RequestBuilder::append(aid, *sid)
                    .prompt(&[5, 6])
                    .max_new(2)
                    .keep(false), // release on completion
            )
            .unwrap();
        let (streamed, done2) = client.read_turn(aid).unwrap();
        assert_eq!(done2.field_str("event").unwrap(), "done", "{done2}");
        assert_eq!(done2.field_i64("session").unwrap() as u64, *sid);
        assert_eq!(streamed.len(), 2);
        let occ2 =
            done2.field_i64("hi_slots").unwrap() + done2.field_i64("lo_slots").unwrap();
        assert!(occ2 > occ1, "cache carried over on append: {occ1} -> {occ2}");
    }

    // All sessions released.
    let v = stats(&stack_addr);
    assert_eq!(v.field_i64("parked_sessions").unwrap(), 0);
    assert_eq!(v.field_i64("pool_outstanding_blocks").unwrap(), 0);
}

/// Promotion-enabled multi-turn serving: a conversation that opts into
/// the lo→hi promotion pass (`compression.promotion: true`) runs a full
/// generate→append→append cycle, every `done` event carries the per-turn
/// `promotions`/`thrash_suppressed` counters, the merged stats snapshot
/// agrees with its per-worker rows, and the final (released) turn leaves
/// nothing behind — parked bytes and pooled blocks back to baseline.
#[test]
fn promotion_session_round_trips_leak_free() {
    on_stack(
        2,
        128,
        CoordinatorConfig::default(),
        Duration::ZERO,
        run_promotion_session,
    );
}

fn run_promotion_session(stack_addr: String) {
    let mut client = Client::connect(&stack_addr).unwrap();
    let mut rng = Pcg32::new(0x9907);
    let mut session: Option<u64> = None;
    let mut last_occ = 0i64;
    let turns = 3usize;
    let spec = CompressionSpec::mikv(0.25, "int4").promoted();
    for turn in 0..turns {
        let id = client.next_id();
        let keep = turn + 1 < turns; // final turn releases the session
        let prompt: Vec<i64> = (0..6).map(|_| rng.gen_range(1, VOCAB - 1)).collect();
        let builder = match session {
            Some(sid) => RequestBuilder::append(id, sid)
                .prompt(&prompt)
                .max_new(12)
                .keep(keep),
            None => RequestBuilder::generate(id)
                .prompt(&prompt)
                .max_new(12)
                .keep(keep)
                .compression(spec.clone()),
        };
        client.submit(&builder).unwrap();
        let (streamed, done) = client.read_turn(id).unwrap();
        assert_eq!(done.field_str("event").unwrap(), "done", "{done}");
        assert_eq!(streamed.len(), 12, "budget honoured with promotion on");
        // The per-turn tier-lifecycle counters ride the done event.
        done.field_i64("promotions").expect("done carries promotions");
        done.field_i64("thrash_suppressed")
            .expect("done carries thrash_suppressed");
        let occ = done.field_i64("hi_slots").unwrap() + done.field_i64("lo_slots").unwrap();
        assert!(occ > last_occ, "occupancy carries across turns");
        last_occ = occ;
        session = if keep {
            Some(done.field_i64("session").unwrap() as u64)
        } else {
            None
        };
    }

    // Leak-free end state, and aggregate counters consistent with the
    // per-worker rows.
    let v = stats(&stack_addr);
    assert_eq!(v.field_i64("parked_sessions").unwrap(), 0, "session leak");
    assert_eq!(v.field_i64("parked_bytes").unwrap(), 0, "parked bytes leak");
    assert_eq!(
        v.field_i64("pool_outstanding_blocks").unwrap(),
        0,
        "pooled blocks leak"
    );
    let total = v.field_i64("promotions").unwrap();
    let rows_sum: i64 = v
        .field_arr("workers")
        .unwrap()
        .iter()
        .map(|r| r.field_i64("promotions").unwrap())
        .sum();
    assert_eq!(total, rows_sum, "aggregate == sum of worker rows");
    let thrash = v.field_i64("thrash_suppressed").unwrap();
    let thrash_sum: i64 = v
        .field_arr("workers")
        .unwrap()
        .iter()
        .map(|r| r.field_i64("thrash_suppressed").unwrap())
        .sum();
    assert_eq!(thrash, thrash_sum);
}

/// TTL sweep: with a zero TTL a kept session is dropped by the owning
/// worker's next sweep (which runs in the same iteration that parked it),
/// its pooled blocks return to baseline, and a follow-up `append` answers
/// `session_not_found` — the registry cannot leak host bytes.
#[test]
fn ttl_sweep_returns_parked_bytes_to_baseline() {
    let cfg = CoordinatorConfig {
        session_ttl: Duration::ZERO,
        ..CoordinatorConfig::default()
    };
    on_stack(2, 64, cfg, Duration::ZERO, run_ttl_sweep);
}

fn run_ttl_sweep(stack_addr: String) {
    let mut client = Client::connect(&stack_addr).unwrap();

    let id = client.next_id();
    client
        .submit(
            &RequestBuilder::generate(id)
                .prompt(&[1, 2, 3])
                .max_new(3)
                .keep(true)
                .compression(CompressionSpec::mikv(0.5, "int4")),
        )
        .unwrap();
    let (_, done) = client.read_turn(id).unwrap();
    assert_eq!(done.field_str("event").unwrap(), "done", "{done}");
    let sid = done.field_i64("session").unwrap() as u64;

    // The sweep in the parking iteration already dropped it (TTL = 0).
    let aid = client.next_id();
    client
        .submit(&RequestBuilder::append(aid, sid).prompt(&[4]).max_new(1))
        .unwrap();
    let (_, term) = client.read_turn(aid).unwrap();
    assert_eq!(term.field_str("event").unwrap(), "error", "{term}");
    assert_eq!(term.field_str("code").unwrap(), "session_not_found");

    let v = stats(&stack_addr);
    assert_eq!(v.field_i64("parked_sessions").unwrap(), 0);
    assert_eq!(v.field_i64("parked_bytes").unwrap(), 0);
    assert_eq!(v.field_i64("pool_outstanding_blocks").unwrap(), 0);
}

/// Cancel across the sharded runtime: a long in-flight turn (throttled by
/// the stub's decode delay, synchronized by its first streamed token) is
/// found and cancelled by the broadcast; a second concurrent short turn on
/// the same connection keeps its own contiguous stream throughout; a
/// cancel for an unknown id folds into exactly one `found: false` answer.
#[test]
fn cancel_broadcast_finds_inflight_turn_and_streams_stay_isolated() {
    on_stack(
        4,
        2048,
        CoordinatorConfig::default(),
        Duration::from_millis(2),
        run_cancel_broadcast,
    );
}

fn run_cancel_broadcast(stack_addr: String) {
    let mut client = Client::connect(&stack_addr).unwrap();

    // Long turn A (even via the cache-full path it would take ~4 s to end
    // naturally — the throttle guarantees the millisecond-scale cancel
    // beats it with orders-of-magnitude margin) and short turn B,
    // concurrently.
    let id_a = client.next_id();
    client
        .submit(
            &RequestBuilder::generate(id_a)
                .prompt(&[9, 9, 9])
                .max_new(100_000)
                .compression(CompressionSpec::mikv(0.25, "int4")),
        )
        .unwrap();
    let id_b = client.next_id();
    client
        .submit(
            &RequestBuilder::generate(id_b)
                .prompt(&[1, 2])
                .max_new(3)
                .compression(CompressionSpec::full()),
        )
        .unwrap();

    // Wait for A's first token (proves A is decoding), collecting whatever
    // B interleaves meanwhile.
    let mut b_stream = Vec::new();
    let mut b_done: Option<Json> = None;
    let mut a_tokens = 0usize;
    while a_tokens == 0 {
        let v = client.recv().unwrap();
        let id = v.field_i64("id").unwrap();
        match (id, v.field_str("event").unwrap()) {
            (i, "token") if i == id_a as i64 => a_tokens += 1,
            (i, "token") if i == id_b as i64 => {
                assert_eq!(v.field_i64("i").unwrap(), b_stream.len() as i64);
                b_stream.push(v.field_i64("t").unwrap());
            }
            (i, "done") if i == id_b as i64 => b_done = Some(v),
            other => panic!("unexpected {other:?}: {v}"),
        }
    }

    // Cancel A; keep draining A tokens / B events until both terminals.
    let id_c = client.next_id();
    client.submit(&RequestBuilder::cancel(id_c, id_a)).unwrap();
    let mut a_done: Option<Json> = None;
    let mut cancel_answers = 0usize;
    while a_done.is_none() || b_done.is_none() || cancel_answers == 0 {
        let v = client.recv().unwrap();
        let id = v.field_i64("id").unwrap();
        match (id, v.field_str("event").unwrap()) {
            (i, "token") if i == id_a as i64 => a_tokens += 1,
            (i, "done") if i == id_a as i64 => a_done = Some(v),
            (i, "token") if i == id_b as i64 => {
                assert_eq!(v.field_i64("i").unwrap(), b_stream.len() as i64);
                b_stream.push(v.field_i64("t").unwrap());
            }
            (i, "done") if i == id_b as i64 => b_done = Some(v),
            (i, "cancelled") if i == id_c as i64 => {
                cancel_answers += 1;
                let found = v.field("found").unwrap() == &Json::Bool(true);
                assert!(found, "in-flight turn must be found: {v}");
            }
            other => panic!("unexpected {other:?}: {v}"),
        }
    }
    let a_done = a_done.unwrap();
    assert_eq!(
        a_done.field("cancelled").unwrap(),
        &Json::Bool(true),
        "{a_done}"
    );
    let partial = a_done.field_arr("tokens").unwrap().len();
    assert!(partial >= 1 && partial < 100_000, "partial tokens: {partial}");
    assert_eq!(cancel_answers, 1, "one aggregated cancel answer");

    // B was untouched: full budget, contiguous stream matching its done.
    let b_done = b_done.unwrap();
    let b_tokens: Vec<i64> = b_done
        .field_arr("tokens")
        .unwrap()
        .iter()
        .filter_map(Json::as_i64)
        .collect();
    assert_eq!(b_stream, b_tokens);
    assert_eq!(b_tokens.len(), 3);
    assert_eq!(b_tokens, expect_generate_tokens(&[1, 2], 3));

    // Unknown-target cancel: exactly one aggregated found=false answer.
    let id_u = client.next_id();
    client.submit(&RequestBuilder::cancel(id_u, 424242)).unwrap();
    let (_, v) = client.read_turn(id_u).unwrap();
    assert_eq!(v.field_str("event").unwrap(), "cancelled");
    assert_eq!(v.field("found").unwrap(), &Json::Bool(false));
}

/// Shed order over the wire, end to end: with the worker saturated and the
/// backlog full, a batch-lane arrival is rejected outright, and an
/// interactive arrival evicts the *newest batch* turn instead of being
/// rejected — both with a structured `overloaded` error carrying the
/// configured `retry_after_ms` hint. Active work is never evicted. The
/// whole sequence is submitted back-to-back on one connection, so the
/// scheduler processes the ops in wire order and the outcome is
/// deterministic (no sleeps, no timing guesses).
#[test]
fn qos_sheds_batch_lane_first_over_the_wire() {
    let qos = QosConfig {
        inflight_per_worker: 1,
        max_backlog: 2,
        retry_after_ms: 25,
        ..QosConfig::default()
    };
    on_stack_qos(1, 2048, qos, Duration::from_millis(2), run_shed_order);
}

fn run_shed_order(stack_addr: String) {
    let mut client = Client::connect(&stack_addr).unwrap();
    // A: long interactive turn → dispatched (inflight cap 1), occupies the
    // worker for ~100ms of throttled decode.
    let id_a = client.next_id();
    client
        .submit(&RequestBuilder::generate(id_a).prompt(&[9, 9, 9]).max_new(50))
        .unwrap();
    // B (interactive) and C (batch) fill the 2-slot backlog.
    let id_b = client.next_id();
    client
        .submit(&RequestBuilder::generate(id_b).prompt(&[1, 2, 3]).max_new(2))
        .unwrap();
    let id_c = client.next_id();
    client
        .submit(
            &RequestBuilder::generate(id_c)
                .prompt(&[4, 5, 6])
                .max_new(2)
                .priority(Priority::Batch),
        )
        .unwrap();
    // D (batch) arrives over a full backlog → rejected outright.
    let id_d = client.next_id();
    client
        .submit(
            &RequestBuilder::generate(id_d)
                .prompt(&[7, 8, 9])
                .max_new(2)
                .priority(Priority::Batch),
        )
        .unwrap();
    // E (interactive) arrives over a full backlog with a batch turn
    // waiting → C is shed to make room, E is admitted.
    let id_e = client.next_id();
    client
        .submit(&RequestBuilder::generate(id_e).prompt(&[2, 4, 6]).max_new(2))
        .unwrap();

    let mut terminals: HashMap<i64, Json> = HashMap::new();
    let mut tokens: HashMap<i64, usize> = HashMap::new();
    while terminals.len() < 5 {
        let v = client.recv().unwrap();
        let id = v.field_i64("id").unwrap();
        match v.field_str("event").unwrap() {
            "token" => *tokens.entry(id).or_default() += 1,
            "done" | "error" => {
                terminals.insert(id, v);
            }
            other => panic!("unexpected event {other}: {v}"),
        }
    }

    for (id, want_tokens) in [(id_a, 50usize), (id_b, 2), (id_e, 2)] {
        let v = &terminals[&(id as i64)];
        assert_eq!(v.field_str("event").unwrap(), "done", "turn {id}: {v}");
        assert_eq!(tokens.get(&(id as i64)), Some(&want_tokens), "turn {id}");
    }
    for id in [id_c, id_d] {
        let v = &terminals[&(id as i64)];
        assert_eq!(v.field_str("event").unwrap(), "error", "turn {id}: {v}");
        assert_eq!(v.field_str("code").unwrap(), "overloaded", "turn {id}");
        assert_eq!(
            v.field_i64("retry_after_ms").unwrap(),
            25,
            "shed rejection carries the configured hint: {v}"
        );
        assert_eq!(tokens.get(&(id as i64)), None, "shed turn streamed nothing");
    }

    // Both rejections came out of the batch lane; nothing is left queued
    // or in flight, and the interactive lane was never shed.
    let v = stats(&stack_addr);
    assert_eq!(v.field_i64("shed_batch").unwrap(), 2, "{v}");
    assert_eq!(v.field_i64("shed_interactive").unwrap(), 0, "{v}");
    assert_eq!(v.field_i64("rate_limited").unwrap(), 0, "{v}");
    assert_eq!(v.field_i64("qos_queued").unwrap(), 0, "{v}");
    assert_eq!(v.field_i64("admitted_in_flight").unwrap(), 0, "{v}");
}

/// Deficit-round-robin fairness at 4 workers: one adversarial connection
/// pipelines 24 turns (one tenant hogging every queue) while 4
/// well-behaved connections each run 4 sequential turns. With per-tenant
/// DRR the well-behaved turns ride round-robin past the chatty backlog, so
/// each well-behaved connection's **worst** turn latency stays a small
/// fraction of the chatty drain time (FCFS head-of-line blocking would put
/// the first well-behaved turn behind ~6 queued chatty turns, most of the
/// drain). The bound is relative to the measured chatty wall-clock, so a
/// slow machine scales both sides equally.
#[test]
fn qos_fair_queuing_bounds_one_chatty_connection_at_four_workers() {
    let qos = QosConfig {
        // quantum ≈ one turn cost (3 prompt + 4 budget): tenants alternate
        // turn-for-turn instead of draining 9-turn quanta.
        quantum: 8,
        inflight_per_worker: 1,
        ..QosConfig::default()
    };
    on_stack_qos(4, 128, qos, Duration::from_millis(5), run_fairness);
}

fn run_fairness(stack_addr: String) {
    const CHATTY_TURNS: usize = 24;
    const WB_CONNS: usize = 4;
    const WB_TURNS: usize = 4;
    let barrier = Arc::new(Barrier::new(WB_CONNS + 1));

    let addr = stack_addr.clone();
    let gate = barrier.clone();
    let chatty = std::thread::spawn(move || {
        let mut client = Client::connect(&addr).unwrap();
        for _ in 0..CHATTY_TURNS {
            let id = client.next_id();
            client
                .submit(&RequestBuilder::generate(id).prompt(&[9, 9, 9]).max_new(4))
                .unwrap();
        }
        gate.wait();
        let t0 = Instant::now();
        let mut done = 0usize;
        while done < CHATTY_TURNS {
            let v = client.recv().unwrap();
            match v.field_str("event").unwrap() {
                "token" => {}
                "done" => done += 1,
                other => panic!("chatty turn failed ({other}): {v}"),
            }
        }
        t0.elapsed()
    });

    let mut wb = Vec::new();
    for conn in 0..WB_CONNS {
        let addr = stack_addr.clone();
        let gate = barrier.clone();
        wb.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            gate.wait();
            let mut worst = Duration::ZERO;
            for _ in 0..WB_TURNS {
                let id = client.next_id();
                let t0 = Instant::now();
                client
                    .submit(
                        &RequestBuilder::generate(id)
                            .prompt(&[1 + conn as i64, 2, 3])
                            .max_new(4),
                    )
                    .unwrap();
                let (streamed, done) = client.read_turn(id).unwrap();
                assert_eq!(done.field_str("event").unwrap(), "done", "{done}");
                assert_eq!(streamed.len(), 4, "budget honoured under contention");
                worst = worst.max(t0.elapsed());
            }
            worst
        }));
    }

    let chatty_wall = chatty.join().expect("chatty connection");
    let worsts: Vec<Duration> = wb
        .into_iter()
        .map(|h| h.join().expect("well-behaved connection"))
        .collect();
    let max = *worsts.iter().max().unwrap();
    let min = *worsts.iter().min().unwrap();

    // Every well-behaved p99 (worst of 4 turns) is bounded by the deficit
    // share: a small slice of the chatty drain, not most of it.
    assert!(
        max < chatty_wall.mul_f64(0.6),
        "well-behaved worst {max:?} not bounded by chatty drain {chatty_wall:?} \
         (per-conn worsts: {worsts:?})"
    );
    // ...and the per-connection spread stays tight: no well-behaved
    // connection is starved relative to another.
    let spread = max.as_secs_f64() / min.as_secs_f64().max(1e-9);
    assert!(
        spread < 4.0,
        "per-conn p99 spread {spread:.2} too wide: {worsts:?}"
    );

    // Nothing was shed to achieve this, and the stack drained clean.
    let v = stats(&stack_addr);
    assert_eq!(v.field_i64("shed_batch").unwrap(), 0, "{v}");
    assert_eq!(v.field_i64("shed_interactive").unwrap(), 0, "{v}");
    assert_eq!(
        v.field_i64("completed").unwrap(),
        (CHATTY_TURNS + WB_CONNS * WB_TURNS) as i64
    );
    assert_eq!(v.field_i64("qos_queued").unwrap(), 0);
    assert_eq!(v.field_i64("admitted_in_flight").unwrap(), 0);
}
