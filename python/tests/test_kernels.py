"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/precisions/mask densities; `assert_allclose`
against `ref.py` is the core correctness signal of the compile path.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.mikv_attn import mikv_attention
from compile.kernels.prefill_attn import prefill_attention
from compile.kernels.quant import dequantize_block, quantize_block

F32 = np.float32


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(F32))


# ----------------------------------------------------------------------
# quantize / dequantize
# ----------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from([2, 3, 4, 8]),
    group=st.sampled_from([2, 4, 8]),
    ngroups=st.integers(1, 4),
    n=st.integers(1, 90),
    seed=st.integers(0, 2**31),
)
def test_quant_kernel_matches_ref(bits, group, ngroups, n, seed):
    rng = np.random.default_rng(seed)
    d = group * ngroups
    x = rand(rng, n, d) * 3.0
    got = quantize_block(x, bits=bits, group=group, use_pallas=True)
    want = ref.quantize_ref(x, bits, group)
    # scales/zeros may differ by one f16 ULP when XLA fuses (hi-lo)/levels
    # differently on an f16 rounding boundary; codes by ±1 level at the
    # corresponding round-half ties. What must agree tightly is the
    # dequantized reconstruction.
    for g, w, name, tol in zip(got, want, ["codes", "scales", "zeros"],
                               [1.0, 2.0 ** -10, 2.0 ** -10]):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=tol, atol=max(tol * 1e-2, 1e-6),
            err_msg=name,
        )
    deq_got = ref.dequantize_ref(*got, group)
    deq_want = ref.dequantize_ref(*want, group)
    np.testing.assert_allclose(
        np.asarray(deq_got), np.asarray(deq_want), rtol=1e-2, atol=1e-2
    )


@settings(max_examples=15, deadline=None)
@given(
    bits=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31),
)
def test_quant_roundtrip_error_bound(bits, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, 17, 8) * 2.0
    codes, scales, zeros = quantize_block(x, bits=bits, group=4)
    y = dequantize_block(codes, scales, zeros, group=4)
    # |err| <= alpha/2 + f16 metadata slop
    step = np.asarray(scales).repeat(4, axis=-1).reshape(17, 8)
    slop = (np.abs(np.asarray(scales)) * 16 + np.abs(np.asarray(zeros))).repeat(4, -1).reshape(17, 8) / 2048
    assert (np.abs(np.asarray(y - x)) <= step / 2 + slop + 1e-6).all()


def test_quant_constant_rows_exact():
    x = jnp.full((5, 8), 1.25, dtype=jnp.float32)
    codes, scales, zeros = quantize_block(x, bits=2, group=4)
    np.testing.assert_array_equal(np.asarray(codes), 0.0)
    y = dequantize_block(codes, scales, zeros, group=4)
    np.testing.assert_allclose(np.asarray(y), 1.25)


def test_quant_codes_within_levels():
    rng = np.random.default_rng(3)
    for bits in [2, 3, 4, 8]:
        x = rand(rng, 33, 16) * 10
        codes, _, _ = quantize_block(x, bits=bits, group=8)
        c = np.asarray(codes)
        assert c.min() >= 0 and c.max() <= (1 << bits) - 1
        assert (c == np.round(c)).all()


# ----------------------------------------------------------------------
# fused mixed-precision decode attention
# ----------------------------------------------------------------------


def make_mikv_inputs(rng, b, h, g, s, d, group, hi_p=0.3, lo_p=0.5):
    ng = d // group
    hi = (rng.random((b, h, s)) < hi_p).astype(F32)
    lo = ((rng.random((b, h, s)) < lo_p) * (1 - hi)).astype(F32)
    # guarantee at least one attendable slot per plane (self token always
    # exists in the kernel, so all-zero masks are legal too — covered below)
    return dict(
        q=rand(rng, b, h, g, d),
        k_new=rand(rng, b, h, d),
        v_new=rand(rng, b, h, d),
        k_hi=rand(rng, b, h, s, d),
        v_hi=rand(rng, b, h, s, d),
        hi_mask=jnp.asarray(hi),
        k_lo_codes=jnp.asarray(rng.integers(0, 16, (b, h, s, d)).astype(F32)),
        k_lo_scale=jnp.asarray((0.01 + rng.random((b, h, s, ng))).astype(F32)),
        k_lo_zero=rand(rng, b, h, s, ng),
        v_lo_codes=jnp.asarray(rng.integers(0, 16, (b, h, s, d)).astype(F32)),
        v_lo_scale=jnp.asarray((0.01 + rng.random((b, h, s, ng))).astype(F32)),
        v_lo_zero=rand(rng, b, h, s, ng),
        lo_mask=jnp.asarray(lo),
        inv_b=jnp.asarray((0.5 + rng.random((b, h, d))).astype(F32)),
    )


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 3),
    g=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([1, 7, 16, 33]),
    group_half=st.booleans(),
    seed=st.integers(0, 2**31),
)
def test_mikv_attention_matches_ref(b, h, g, s, group_half, seed):
    rng = np.random.default_rng(seed)
    d = 8
    group = d // 2 if group_half else d
    ins = make_mikv_inputs(rng, b, h, g, s, d, group)
    got = mikv_attention(**ins, group=group, use_pallas=True)
    want = mikv_attention(**ins, group=group, use_pallas=False)
    for a, w, name in zip(got, want, ["out", "attn_prev", "attn_self"]):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(w), rtol=1e-5, atol=1e-5, err_msg=name
        )


def test_mikv_attention_empty_cache_attends_self_only():
    """All masks zero ⇒ the only attendable token is the new one."""
    rng = np.random.default_rng(1)
    ins = make_mikv_inputs(rng, 1, 1, 2, 8, 8, 4, hi_p=0.0, lo_p=0.0)
    out, attn_prev, attn_self = mikv_attention(**ins, group=4, use_pallas=True)
    np.testing.assert_allclose(np.asarray(attn_prev), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(attn_self), 2.0, atol=1e-5)  # G heads × prob 1
    want = np.asarray(ins["v_new"][0, 0])
    np.testing.assert_allclose(np.asarray(out[0, 0, 0]), want, rtol=1e-5, atol=1e-6)


def test_mikv_attention_probs_sum_to_one():
    rng = np.random.default_rng(2)
    g = 3
    ins = make_mikv_inputs(rng, 2, 2, g, 12, 8, 4)
    _, attn_prev, attn_self = mikv_attention(**ins, group=4, use_pallas=True)
    total = np.asarray(attn_prev).sum(-1) + np.asarray(attn_self)
    np.testing.assert_allclose(total, float(g), rtol=1e-5)


def test_mikv_attention_hi_tier_exact_when_all_hi():
    """With everything hi and identity balancer, MiKV attention must equal
    plain full attention over the same keys."""
    rng = np.random.default_rng(4)
    b, h, g, s, d = 1, 2, 2, 10, 8
    ins = make_mikv_inputs(rng, b, h, g, s, d, 4, hi_p=1.0, lo_p=0.0)
    ins["inv_b"] = jnp.ones((b, h, d), jnp.float32)
    out, attn_prev, attn_self = mikv_attention(**ins, group=4, use_pallas=True)

    # reference: oracle attention with k = S+1 (no sparsity)
    import jax

    fn = jax.vmap(jax.vmap(ref.oracle_attention_ref, in_axes=(0,) * 6 + (None,)),
                  in_axes=(0,) * 6 + (None,))
    want_out, want_prev, want_self = fn(
        ins["q"], ins["k_new"], ins["v_new"], ins["k_hi"], ins["v_hi"],
        ins["hi_mask"], jnp.asarray(s + 1, dtype=jnp.int64),
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_out), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(attn_prev), np.asarray(want_prev), rtol=1e-5, atol=1e-5)


def test_mikv_attention_balancer_identity_equivalence():
    """inv_b=1 must equal the explicit no-balancer path."""
    rng = np.random.default_rng(5)
    ins = make_mikv_inputs(rng, 1, 1, 2, 9, 8, 4)
    ins_id = dict(ins)
    ins_id["inv_b"] = jnp.ones_like(ins["inv_b"])
    got = mikv_attention(**ins_id, group=4, use_pallas=True)
    want = mikv_attention(**ins_id, group=4, use_pallas=False)
    for a, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w), rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# prefill attention
# ----------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 3),
    g=st.sampled_from([1, 2]),
    s=st.sampled_from([2, 9, 24]),
    seed=st.integers(0, 2**31),
)
def test_prefill_attention_matches_ref(b, h, g, s, seed):
    rng = np.random.default_rng(seed)
    d = 8
    q = rand(rng, b, h, g, s, d)
    k = rand(rng, b, h, s, d)
    v = rand(rng, b, h, s, d)
    lens = rng.integers(1, s + 1, size=b)
    lm = np.zeros((b, s), F32)
    for i, n in enumerate(lens):
        lm[i, :n] = 1
    got = prefill_attention(q, k, v, jnp.asarray(lm), use_pallas=True)
    want = prefill_attention(q, k, v, jnp.asarray(lm), use_pallas=False)
    for a, w, name in zip(got, want, ["out", "acc", "qmax", "kmax"]):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(w), rtol=1e-4, atol=1e-5, err_msg=name
        )


def test_prefill_attn_acc_is_probability_mass():
    """Column sums over live rows: total mass = number of live queries ×
    group heads."""
    rng = np.random.default_rng(7)
    b, h, g, s, d = 1, 2, 2, 12, 8
    q, k, v = rand(rng, b, h, g, s, d), rand(rng, b, h, s, d), rand(rng, b, h, s, d)
    lm = np.zeros((b, s), F32)
    lm[0, :9] = 1
    _, acc, _, _ = prefill_attention(q, k, v, jnp.asarray(lm), use_pallas=True)
    np.testing.assert_allclose(np.asarray(acc).sum(-1), 9.0 * g, rtol=1e-4)


def test_prefill_causality():
    """Changing a future key must not affect earlier attention outputs."""
    rng = np.random.default_rng(8)
    b, h, g, s, d = 1, 1, 1, 10, 8
    q = rand(rng, b, h, g, s, d)
    k = np.asarray(rand(rng, b, h, s, d)).copy()
    v = np.asarray(rand(rng, b, h, s, d)).copy()
    lm = np.ones((b, s), F32)
    out1, *_ = prefill_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(lm))
    k2, v2 = k.copy(), v.copy()
    k2[0, 0, 7:] += 5.0
    v2[0, 0, 7:] -= 3.0
    out2, *_ = prefill_attention(jnp.asarray(q), jnp.asarray(k2), jnp.asarray(v2), jnp.asarray(lm))
    np.testing.assert_allclose(
        np.asarray(out1)[0, 0, 0, :7], np.asarray(out2)[0, 0, 0, :7], rtol=1e-5, atol=1e-6
    )


# ----------------------------------------------------------------------
# RoPE properties
# ----------------------------------------------------------------------


def test_rope_preserves_norm():
    rng = np.random.default_rng(9)
    x = rand(rng, 4, 16)
    cos, sin = ref.rope_angles(jnp.asarray(np.arange(4), jnp.float32), 16)
    y = ref.rope_ref(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_rope_relative_position_invariance():
    """q·k after RoPE depends only on the position difference."""
    rng = np.random.default_rng(10)
    d = 16
    q = rand(rng, d)
    k = rand(rng, d)

    def score(pq, pk):
        cq, sq = ref.rope_angles(jnp.asarray(float(pq)), d)
        ck, sk = ref.rope_angles(jnp.asarray(float(pk)), d)
        return float(ref.rope_ref(q, cq, sq) @ ref.rope_ref(k, ck, sk))

    assert abs(score(5, 3) - score(9, 7)) < 1e-4
    assert abs(score(0, 0) - score(11, 11)) < 1e-4
    assert abs(score(5, 3) - score(5, 4)) > 1e-6  # sanity: not constant


def test_rope_position_zero_is_identity():
    rng = np.random.default_rng(11)
    x = rand(rng, 8)
    cos, sin = ref.rope_angles(jnp.asarray(0.0), 8)
    np.testing.assert_allclose(np.asarray(ref.rope_ref(x, cos, sin)), np.asarray(x), rtol=1e-6)
