"""AOT pipeline contracts: graph I/O tables match the lowered functions."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, corpus
from compile.model import CONFIGS, param_names


CFG = CONFIGS["cfg-tiny"]


def test_graph_io_weight_prefix():
    for kind in ["prefill", "decode_mikv", "decode_full"]:
        ins, outs = aot.graph_io(CFG, kind, 1)
        names = [i["name"] for i in ins]
        # weights first, in param order
        for i, p in enumerate(param_names(CFG)):
            assert names[i] == f"w.{p}"
        assert len(outs) >= 5 or kind == "prefill"


def test_graph_io_shapes_consistent():
    b = 2
    ins, _ = aot.graph_io(CFG, "decode_mikv", b)
    by_name = {i["name"]: i for i in ins}
    l, h, s, d = CFG.n_layers, CFG.n_kv_heads, CFG.max_seq, CFG.d_head
    assert by_name["token"]["shape"] == [b]
    assert by_name["pos"]["shape"] == [b]
    assert by_name["k_hi"]["shape"] == [b, l, h, s, d]
    assert by_name["k_lo_scale"]["shape"] == [b, l, h, s, CFG.n_groups]
    assert by_name["inv_b"]["shape"] == [b, l, h, d]
    assert by_name["token"]["dtype"] == "i64"


def test_lowered_graph_parameter_count_matches_io():
    """The HLO text must declare exactly len(inputs) parameters."""
    ins, _ = aot.graph_io(CFG, "decode_full", 1)
    text = aot.lower_graph(CFG, "decode_full", 1)
    import re

    entry = text[text.index("ENTRY") :]
    params = re.findall(r"parameter\(\d+\)", entry)
    assert len(set(params)) == len(ins)


def test_corpus_constants_complete():
    consts = aot.corpus_constants()
    for k in ["BOS", "ANS", "KEY_BASE", "KEY_N", "VAL_BASE", "VAL_N", "VOCAB"]:
        assert k in consts
    assert consts["VOCAB"] == corpus.VOCAB
    assert consts["KEY_N"] == corpus.KEY_N


def test_goldens_cover_all_graph_inputs():
    """Golden fixtures must contain every non-weight input of each graph."""
    from compile.model import init_params

    params = init_params(CFG, jax.random.PRNGKey(0))
    gold = aot.make_goldens(CFG, params, b=1, seed=7)
    nw = len(param_names(CFG))
    for kind in ["prefill", "decode_mikv", "decode_full"]:
        ins, outs = aot.graph_io(CFG, kind, 1)
        for spec in ins[nw:]:
            key = f"{kind}.in.{spec['name']}"
            assert key in gold, key
            assert list(gold[key].shape) == spec["shape"], key
        for o in outs:
            assert f"{kind}.out.{o}" in gold
