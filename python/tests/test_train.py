"""Training-loop smoke tests (fast: tiny config, few steps)."""

import jax
import numpy as np

from compile import corpus
from compile.model import CONFIGS, init_params
from compile.train import (
    adam_init,
    adam_step,
    loss_fn,
    retrieval_probe,
    save_checkpoint,
    load_checkpoint,
    train,
)

CFG = CONFIGS["cfg-tiny"]


def test_loss_decreases_quickly():
    params, curve = train(CFG, steps=40, batch=4, seq_len=48, log_every=5, log=lambda *a: None)
    first = curve[0][1]
    best = min(l for _, l in curve)
    assert best < first * 0.85, f"loss did not decrease: first {first}, best {best}"


def test_overfit_single_batch():
    """The model must be able to memorize a fixed batch (training-path bug
    detector: loss → ~0 within 150 steps)."""
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    samples = [corpus.gen_lineret(rng, 4) for _ in range(4)]
    tokens, len_mask, loss_mask = corpus.batch_samples(samples, 40)
    tokens, len_mask, loss_mask = map(jnp.asarray, (tokens, len_mask, loss_mask))
    params = init_params(CFG, jax.random.PRNGKey(0))
    opt = adam_init(params)

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(CFG, p, tokens, len_mask, loss_mask)
        )(params)
        params, opt = adam_step(params, grads, opt, 2e-3)
        return params, opt, loss

    loss = None
    for _ in range(250):
        params, opt, loss = step(params, opt)
    assert float(loss) < 0.2, f"failed to overfit: loss {float(loss)}"


def test_retrieval_probe_range():
    params = init_params(CFG, jax.random.PRNGKey(1))
    acc = retrieval_probe(CFG, params, seq_len=48, n=8)
    assert 0.0 <= acc <= 1.0


def test_checkpoint_roundtrip(tmp_path):
    params = init_params(CFG, jax.random.PRNGKey(2))
    path = str(tmp_path / "w.mikv")
    save_checkpoint(path, CFG, {k: np.asarray(v) for k, v in params.items()}, {"train_steps": 3})
    loaded, meta = load_checkpoint(path)
    assert meta["train_steps"] == 3
    assert meta["model"] == CFG.name
    for k in params:
        np.testing.assert_array_equal(np.asarray(loaded[k]), np.asarray(params[k]))
