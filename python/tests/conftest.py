import os
import sys

import jax

# S64 graph contracts (see compile/aot.py).
jax.config.update("jax_enable_x64", True)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
