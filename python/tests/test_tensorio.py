"""Round-trip tests for the .mikv tensor container."""

import numpy as np
import pytest

from compile.tensorio import ALIGN, MAGIC, read_tensors, write_tensors


def test_roundtrip_multiple_tensors(tmp_path):
    path = str(tmp_path / "t.mikv")
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.array([1, -2, 3], dtype=np.int64),
        "scalar": np.array(7.5, dtype=np.float32),
        "empty": np.zeros((0, 4), dtype=np.float32),
    }
    write_tensors(path, tensors, {"k": "v", "n": 3})
    tf = read_tensors(path)
    assert tf.meta == {"k": "v", "n": 3}
    assert tf.names() == ["a", "b", "scalar", "empty"]
    for name, arr in tensors.items():
        np.testing.assert_array_equal(tf[name], arr)
        assert tf[name].dtype == arr.dtype


def test_alignment(tmp_path):
    path = str(tmp_path / "t.mikv")
    write_tensors(path, {"x": np.ones(3, np.float32), "y": np.ones(5, np.float32)})
    with open(path, "rb") as f:
        data = f.read()
    import json
    import struct

    hdrlen = struct.unpack("<Q", data[len(MAGIC) : len(MAGIC) + 8])[0]
    header = json.loads(data[len(MAGIC) + 8 : len(MAGIC) + 8 + hdrlen])
    for e in header["tensors"]:
        assert e["offset"] % ALIGN == 0


def test_bad_magic_rejected(tmp_path):
    path = str(tmp_path / "bad.mikv")
    with open(path, "wb") as f:
        f.write(b"NOTMIKV" + b"\x00" * 64)
    with pytest.raises(ValueError, match="bad magic"):
        read_tensors(path)


def test_unsupported_dtype_rejected(tmp_path):
    with pytest.raises(TypeError):
        write_tensors(str(tmp_path / "x.mikv"), {"x": np.ones(2, np.float64)})


def test_f32_bitexact(tmp_path):
    path = str(tmp_path / "t.mikv")
    rng = np.random.default_rng(0)
    x = rng.standard_normal(1000).astype(np.float32)
    write_tensors(path, {"x": x})
    y = read_tensors(path)["x"]
    assert np.array_equal(x.view(np.uint32), y.view(np.uint32))
