"""L2 model invariants: prefill/decode consistency, GQA, cache semantics."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import corpus
from compile.model import (
    CONFIGS,
    decode_full,
    decode_mikv,
    init_params,
    param_names,
    param_shapes,
    params_to_list,
    prefill,
)

CFG = CONFIGS["cfg-tiny"]


def setup(seed=0):
    params = init_params(CFG, jax.random.PRNGKey(seed))
    return params_to_list(CFG, params)


def prompt(b=1, n=10, seed=0):
    rng = np.random.default_rng(seed)
    s = CFG.max_seq
    tokens = np.zeros((b, s), np.int64)
    lm = np.zeros((b, s), np.float32)
    for i in range(b):
        tokens[i, :n] = rng.integers(1, CFG.vocab, n)
        lm[i, :n] = 1
    return jnp.asarray(tokens), jnp.asarray(lm)


def test_param_shapes_and_count():
    shapes = param_shapes(CFG)
    assert set(shapes) == set(param_names(CFG))
    total = sum(int(np.prod(s)) for s in shapes.values())
    assert total == CFG.param_count()


def test_prefill_shapes_and_padding_invariance():
    flat = setup()
    tokens, lm = prompt(n=8)
    logits, k, v, acc, qmax, kmax = prefill(CFG, flat, tokens, lm, use_pallas=False)
    s = CFG.max_seq
    assert logits.shape == (1, s, CFG.vocab)
    assert k.shape == (1, CFG.n_layers, CFG.n_kv_heads, s, CFG.d_head)
    # garbage in the padding region must not change live logits
    tokens2 = np.asarray(tokens).copy()
    tokens2[0, 20:30] = 13
    logits2, *_ = prefill(CFG, flat, jnp.asarray(tokens2), lm, use_pallas=False)
    np.testing.assert_allclose(
        np.asarray(logits)[0, :8], np.asarray(logits2)[0, :8], rtol=1e-4, atol=1e-5
    )


def test_decode_full_teacher_forcing_parity():
    """decode_full(pos=t, full cache of 0..t-1) == prefill logits at t."""
    flat = setup()
    tokens, lm = prompt(n=12, seed=3)
    logits_pf, k, v, *_ = prefill(CFG, flat, tokens, lm, use_pallas=False)
    s = CFG.max_seq
    for t in [1, 5, 11]:
        mask = np.zeros((1, CFG.n_layers, CFG.n_kv_heads, s), np.float32)
        mask[:, :, :, :t] = 1
        res = decode_full(
            CFG, flat, tokens[:, t], jnp.asarray([t], jnp.int64),
            k, v, jnp.asarray(mask), jnp.asarray(s + 1, jnp.int64),
        )
        np.testing.assert_allclose(
            np.asarray(res[0]), np.asarray(logits_pf)[:, t], rtol=3e-3, atol=3e-4,
            err_msg=f"t={t}",
        )


def test_decode_mikv_all_hi_matches_decode_full():
    """MiKV decode with everything in the hi tier (fp) == full decode."""
    flat = setup()
    tokens, lm = prompt(n=9, seed=4)
    _, k, v, *_ = prefill(CFG, flat, tokens, lm, use_pallas=False)
    s, l, h, d = CFG.max_seq, CFG.n_layers, CFG.n_kv_heads, CFG.d_head
    ng = CFG.n_groups
    t = 9
    mask = np.zeros((1, l, h, s), np.float32)
    mask[:, :, :, :t] = 1
    z = lambda *shape: jnp.zeros(shape, jnp.float32)
    res_mikv = decode_mikv(
        CFG, flat, tokens[:, 0], jnp.asarray([t], jnp.int64),
        k, v, jnp.asarray(mask),
        z(1, l, h, s, d), z(1, l, h, s, ng) + 1.0, z(1, l, h, s, ng),
        z(1, l, h, s, d), z(1, l, h, s, ng) + 1.0, z(1, l, h, s, ng),
        z(1, l, h, s), jnp.ones((1, l, h, d), jnp.float32),
        use_pallas=False,
    )
    res_full = decode_full(
        CFG, flat, tokens[:, 0], jnp.asarray([t], jnp.int64),
        k, v, jnp.asarray(mask), jnp.asarray(s + 1, jnp.int64),
    )
    for a, b, name in zip(res_mikv, res_full,
                          ["logits", "k_new", "v_new", "attn_prev", "attn_self"]):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5, err_msg=name
        )


def test_decode_mikv_pallas_matches_ref_path():
    flat = setup()
    rng = np.random.default_rng(5)
    s, l, h, d = CFG.max_seq, CFG.n_layers, CFG.n_kv_heads, CFG.d_head
    ng = CFG.n_groups
    f = lambda *shape: jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    hi = (rng.random((1, l, h, s)) < 0.4).astype(np.float32)
    lo = ((rng.random((1, l, h, s)) < 0.4) * (1 - hi)).astype(np.float32)
    args = (
        jnp.asarray([3], jnp.int64), jnp.asarray([s // 2], jnp.int64),
        f(1, l, h, s, d), f(1, l, h, s, d), jnp.asarray(hi),
        jnp.asarray(rng.integers(0, 4, (1, l, h, s, d)).astype(np.float32)),
        jnp.asarray((0.1 + rng.random((1, l, h, s, ng))).astype(np.float32)),
        f(1, l, h, s, ng),
        jnp.asarray(rng.integers(0, 4, (1, l, h, s, d)).astype(np.float32)),
        jnp.asarray((0.1 + rng.random((1, l, h, s, ng))).astype(np.float32)),
        f(1, l, h, s, ng),
        jnp.asarray(lo), jnp.asarray((0.5 + rng.random((1, l, h, d))).astype(np.float32)),
    )
    got = decode_mikv(CFG, flat, *args, use_pallas=True)
    want = decode_mikv(CFG, flat, *args, use_pallas=False)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_oracle_topk_full_k_is_identity():
    """oracle_k >= S+1 must equal the exact full-cache decode."""
    flat = setup()
    tokens, lm = prompt(n=7, seed=6)
    _, k, v, *_ = prefill(CFG, flat, tokens, lm, use_pallas=False)
    s, l, h = CFG.max_seq, CFG.n_layers, CFG.n_kv_heads
    mask = np.zeros((1, l, h, s), np.float32)
    mask[:, :, :, :7] = 1
    full = decode_full(CFG, flat, tokens[:, 0], jnp.asarray([7], jnp.int64),
                       k, v, jnp.asarray(mask), jnp.asarray(s + 1, jnp.int64))
    # k = 8 live slots (7 prev + self) — also no sparsification
    same = decode_full(CFG, flat, tokens[:, 0], jnp.asarray([7], jnp.int64),
                       k, v, jnp.asarray(mask), jnp.asarray(8, jnp.int64))
    np.testing.assert_allclose(np.asarray(full[0]), np.asarray(same[0]), rtol=1e-4, atol=1e-5)


def test_oracle_topk_1_attends_single_slot():
    flat = setup()
    tokens, lm = prompt(n=7, seed=7)
    _, k, v, *_ = prefill(CFG, flat, tokens, lm, use_pallas=False)
    s, l, h = CFG.max_seq, CFG.n_layers, CFG.n_kv_heads
    mask = np.zeros((1, l, h, s), np.float32)
    mask[:, :, :, :7] = 1
    res = decode_full(CFG, flat, tokens[:, 0], jnp.asarray([7], jnp.int64),
                      k, v, jnp.asarray(mask), jnp.asarray(1, jnp.int64))
    attn_prev, attn_self = np.asarray(res[3]), np.asarray(res[4])
    # per (plane, q-head) exactly one slot holds probability 1, so the
    # summed mass per plane equals the number of grouped q heads and every
    # entry is integral
    g = CFG.gqa_group
    total = attn_prev.sum(-1) + attn_self
    np.testing.assert_allclose(total, float(g), rtol=1e-5)
    stacked = np.concatenate([attn_prev, attn_self[..., None]], axis=-1)
    np.testing.assert_allclose(stacked, np.round(stacked), atol=1e-5)


def test_gqa_grouping_consistency():
    """A GQA model whose KV heads are replicated to all Q heads must match
    the equivalent MHA model."""
    gqa = CONFIGS["cfg-tiny"]  # 4 q heads, 2 kv heads
    mha = type(gqa)(
        name="tiny-mha", vocab=gqa.vocab, d_model=gqa.d_model,
        n_layers=gqa.n_layers, n_q_heads=4, n_kv_heads=4,
        d_head=gqa.d_head, d_ff=gqa.d_ff, max_seq=gqa.max_seq,
    )
    params_g = init_params(gqa, jax.random.PRNGKey(1))
    params_m = {k: v.copy() for k, v in params_g.items()}
    # replicate each kv head's projection to the two q heads of its group
    d = gqa.d_head
    for i in range(gqa.n_layers):
        for w in ["wk", "wv"]:
            pw = params_g[f"l{i}.{w}"]  # [E, 2*d]
            params_m[f"l{i}.{w}"] = jnp.concatenate(
                [pw[:, :d], pw[:, :d], pw[:, d:], pw[:, d:]], axis=1
            )
    tokens, lm = prompt(n=8, seed=8)
    lg, *_ = prefill(gqa, params_to_list(gqa, params_g), tokens, lm, use_pallas=False)
    lm_, *_ = prefill(mha, params_to_list(mha, params_m), tokens, lm, use_pallas=False)
    np.testing.assert_allclose(
        np.asarray(lg)[0, :8], np.asarray(lm_)[0, :8], rtol=2e-4, atol=1e-5
    )


def test_corpus_samples_are_well_formed():
    rng = np.random.default_rng(0)
    for _ in range(50):
        s = corpus.gen_mixture(rng, 192)
        assert len(s.tokens) <= 192
        assert s.tokens.min() >= 0 and s.tokens.max() < corpus.VOCAB
        assert len(s.loss_mask) == len(s.tokens)
        if s.family in ("lineret", "multihop"):
            # canonical induction: the token before the answer is the query key
            qk = s.tokens[s.answer_start - 1]
            assert corpus.KEY_BASE <= qk < corpus.KEY_BASE + corpus.KEY_N
            np.testing.assert_array_equal(
                s.tokens[s.answer_start : s.answer_start + len(s.answer)], s.answer
            )


def test_corpus_lineret_answer_is_retrievable():
    """The queried key appears exactly once and its value is the answer."""
    rng = np.random.default_rng(1)
    s = corpus.gen_lineret(rng, 8)
    toks = s.tokens.tolist()
    qpos = toks.index(corpus.QUERY)
    key = toks[qpos + 1 : qpos + 1 + corpus.KEY_TOKS]
    # find the record with that key; its value follows immediately
    found = 0
    for i, t in enumerate(toks[:qpos]):
        if t == corpus.REC and toks[i + 1 : i + 1 + corpus.KEY_TOKS] == key:
            val = toks[i + 1 + corpus.KEY_TOKS : i + 1 + corpus.KEY_TOKS + corpus.VAL_TOKS]
            np.testing.assert_array_equal(np.asarray(val), s.answer)
            found += 1
    assert found == 1
