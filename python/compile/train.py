"""Train the reproduction transformer on the synthetic task mixture.

Build-time only (invoked by `aot.py` / `make artifacts`). Adam + cosine
schedule, teacher-forced next-token loss weighted by each sample's loss
mask (answer spans weighted 1.0, context 0.1 — the model must *retrieve*,
not memorize). Training uses the plain-jnp forward (`model.train_forward`);
the Pallas kernels are only in the inference graphs.

The checkpoint (.mikv) is cached: re-running `make artifacts` skips
training when the file already exists with matching config + steps.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import ModelConfig, init_params, train_forward
from .tensorio import read_tensors, write_tensors


def loss_fn(cfg: ModelConfig, params: dict, tokens, len_mask, loss_mask):
    """Weighted next-token cross-entropy."""
    logits = train_forward(cfg, params, tokens, len_mask)  # [B, S, V]
    # predict token t+1 from position t
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]  # [B, S-1]
    w = loss_mask[:, 1:] * len_mask[:, 1:]
    return (nll * w).sum() / jnp.maximum(w.sum(), 1.0)


def retrieval_probe(cfg: ModelConfig, params: dict, seq_len: int, n: int = 32, seed: int = 10_007) -> float:
    """Teacher-forced line-retrieval accuracy on held-out samples — the
    signal that induction has emerged (logged during training)."""
    rng = np.random.default_rng(seed)
    # scale the record count so prompt+answer fits the probe window
    n_lines = max(2, min(10, (seq_len - 10) // 6))
    samples = [corpus.gen_lineret(rng, n_lines) for _ in range(n)]
    samples = [s for s in samples if s.answer_start + corpus.VAL_TOKS < seq_len]
    tokens, len_mask, _ = corpus.batch_samples(samples, seq_len)
    logits = train_forward(cfg, params, jnp.asarray(tokens), jnp.asarray(len_mask))
    pred = np.asarray(jnp.argmax(logits, -1))
    ok = 0
    for i, s in enumerate(samples):
        a = s.answer_start
        ok += all(pred[i, a - 1 + j] == s.tokens[a + j] for j in range(corpus.VAL_TOKS))
    return ok / max(1, len(samples))


def adam_init(params: dict):
    z = lambda: jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z(), "v": z(), "t": jnp.zeros((), jnp.int32)}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.98, eps=1e-9):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t.astype(jnp.float32)), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t.astype(jnp.float32)), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh
    )
    return new, {"m": m, "v": v, "t": t}


def train(
    cfg: ModelConfig,
    *,
    steps: int = 400,
    batch: int = 12,
    seq_len: int | None = None,
    lr: float = 1.5e-3,
    seed: int = 0,
    log_every: int = 25,
    log=print,
) -> tuple[dict, list[tuple[int, float]]]:
    """Train and return (params, loss_curve)."""
    seq_len = seq_len or min(cfg.max_seq, 160)
    rng = np.random.default_rng(seed)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, tokens, len_mask, loss_mask, lr_now):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens, len_mask, loss_mask))(params)
        params, opt = adam_step(params, grads, opt, lr_now)
        return params, opt, loss

    curve = []
    t0 = time.time()
    for step in range(steps):
        samples = [corpus.gen_mixture(rng, seq_len) for _ in range(batch)]
        tokens, len_mask, loss_mask = corpus.batch_samples(samples, seq_len)
        warm = min(1.0, (step + 1) / 40.0)
        cos = 0.5 * (1 + np.cos(np.pi * step / steps))
        lr_now = lr * warm * (0.1 + 0.9 * cos)
        params, opt, loss = step_fn(
            params, opt, jnp.asarray(tokens), jnp.asarray(len_mask),
            jnp.asarray(loss_mask), jnp.float32(lr_now),
        )
        if step % log_every == 0 or step == steps - 1:
            l = float(loss)
            curve.append((step, l))
            probe = retrieval_probe(cfg, params, seq_len) if step % (log_every * 4) == 0 or step == steps - 1 else None
            log(f"  train[{cfg.name}] step {step:4d}/{steps} loss {l:.4f}"
                + (f" lineret {probe:.2f}" if probe is not None else "")
                + f" ({time.time() - t0:.0f}s)")
    return params, curve


def save_checkpoint(path: str, cfg: ModelConfig, params: dict, meta: dict):
    tensors = {name: np.asarray(params[name]) for name in params}
    meta = dict(meta)
    meta.update({
        "model": cfg.name, "vocab": cfg.vocab, "d_model": cfg.d_model,
        "n_layers": cfg.n_layers, "n_q_heads": cfg.n_q_heads,
        "n_kv_heads": cfg.n_kv_heads, "d_head": cfg.d_head,
        "d_ff": cfg.d_ff, "max_seq": cfg.max_seq,
    })
    write_tensors(path, tensors, meta)


def load_checkpoint(path: str) -> tuple[dict, dict]:
    tf = read_tensors(path)
    return {n: jnp.asarray(a) for n, a in tf.tensors.items()}, tf.meta
