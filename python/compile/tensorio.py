"""`.mikv` tensor container — the weight interchange format.

Python (build path) writes trained checkpoints; the rust runtime
(`rust/src/runtime/weights.rs`) reads them. The format is deliberately
trivial so both sides stay dependency-free:

    magic   : b"MIKV\\x01\\n"                      (6 bytes)
    hdrlen  : u64 little-endian                    (8 bytes)
    header  : UTF-8 JSON, `hdrlen` bytes:
              {"meta": {...}, "tensors": [
                  {"name": str, "dtype": "f32"|"i64",
                   "shape": [int, ...], "offset": int, "nbytes": int}, ...]}
    data    : raw little-endian blob; each tensor starts at
              `offset` bytes into the data section, 64-byte aligned.
"""

from __future__ import annotations

import json
import struct
from dataclasses import dataclass

import numpy as np

MAGIC = b"MIKV\x01\n"
ALIGN = 64

_DTYPES = {
    "f32": np.dtype("<f4"),
    "i64": np.dtype("<i8"),
}


def _dtype_name(arr: np.ndarray) -> str:
    if arr.dtype == np.float32:
        return "f32"
    if arr.dtype == np.int64:
        return "i64"
    raise TypeError(f"unsupported dtype {arr.dtype}; cast to float32 or int64")


def write_tensors(path: str, tensors: dict[str, np.ndarray], meta: dict | None = None) -> None:
    """Write a named tensor dict to a .mikv file (order preserved)."""
    entries = []
    blobs = []
    offset = 0
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        dtype = _dtype_name(arr)
        raw = arr.astype(_DTYPES[dtype], copy=False).tobytes()
        pad = (-offset) % ALIGN
        offset += pad
        blobs.append((pad, raw))
        entries.append(
            {
                "name": name,
                "dtype": dtype,
                "shape": list(arr.shape),
                "offset": offset,
                "nbytes": len(raw),
            }
        )
        offset += len(raw)

    header = json.dumps({"meta": meta or {}, "tensors": entries}).encode("utf-8")
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<Q", len(header)))
        f.write(header)
        for pad, raw in blobs:
            f.write(b"\x00" * pad)
            f.write(raw)


@dataclass
class TensorFile:
    """Parsed .mikv file."""

    meta: dict
    tensors: dict[str, np.ndarray]

    def __getitem__(self, name: str) -> np.ndarray:
        return self.tensors[name]

    def names(self) -> list[str]:
        return list(self.tensors.keys())


def read_tensors(path: str) -> TensorFile:
    """Read a .mikv file back into numpy arrays."""
    with open(path, "rb") as f:
        magic = f.read(len(MAGIC))
        if magic != MAGIC:
            raise ValueError(f"{path}: bad magic {magic!r}")
        (hdrlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hdrlen).decode("utf-8"))
        data = f.read()

    tensors: dict[str, np.ndarray] = {}
    for e in header["tensors"]:
        dt = _DTYPES[e["dtype"]]
        raw = data[e["offset"] : e["offset"] + e["nbytes"]]
        arr = np.frombuffer(raw, dtype=dt).reshape(e["shape"]).copy()
        tensors[e["name"]] = arr
    return TensorFile(meta=header["meta"], tensors=tensors)
