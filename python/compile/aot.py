"""AOT pipeline: train → lower → dump goldens → write manifest.

Python runs ONCE here (`make artifacts`); the rust binary is self-contained
afterwards. For every (model config, batch size) this emits HLO **text**
(not serialized protos — the image's xla_extension 0.5.1 rejects jax≥0.5's
64-bit instruction ids; the text parser reassigns ids):

    artifacts/
      manifest.json                     index of everything below
      weights-<cfg>.mikv                trained checkpoint (runtime inputs)
      <cfg>-prefill-b<B>.hlo.txt
      <cfg>-decode_mikv-b<B>.hlo.txt
      <cfg>-decode_full-b<B>.hlo.txt
      <cfg>-quant<bits>.hlo.txt         bulk quantization (ablation path)
      golden-<cfg>.mikv                 parity fixtures for rust tests

Usage: python -m compile.aot --out ../artifacts [--models a,b] [--steps N]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax

# The graph contracts use S64 scalars/ids (tokens, pos, oracle_k); without
# x64 jax silently downcasts them to S32 and the rust-side literals would
# mismatch the compiled parameter shapes.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus
from .kernels.quant import quantize_block
from .model import (
    CONFIGS,
    ModelConfig,
    decode_full,
    decode_mikv,
    init_params,
    param_names,
    param_shapes,
    params_to_list,
    prefill,
)
from .tensorio import read_tensors, write_tensors
from .train import load_checkpoint, save_checkpoint, train

# Batch sizes emitted per model.
BATCHES = {"cfg-tiny": [1, 2], "cfg-s": [1, 4], "cfg-s-gqa": [1], "cfg-m": [1]}
# Training steps per model (cfg-tiny stays random-init: goldens only).
TRAIN_STEPS = {"cfg-tiny": 0, "cfg-s": 900, "cfg-s-gqa": 120, "cfg-m": 200}
QUANT_BITS = [2, 3, 4, 8]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ----------------------------------------------------------------------
# Graph input/output contracts (mirrored by rust/src/runtime/artifacts.rs)
# ----------------------------------------------------------------------


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def weight_specs(cfg: ModelConfig):
    shapes = param_shapes(cfg)
    return [spec(shapes[n]) for n in param_names(cfg)]


def cache_dims(cfg: ModelConfig, b: int):
    return b, cfg.n_layers, cfg.n_kv_heads, cfg.max_seq, cfg.d_head


def graph_io(cfg: ModelConfig, kind: str, b: int):
    """(input name/shape/dtype list, output name list) for a graph kind."""
    l, h, s, d = cfg.n_layers, cfg.n_kv_heads, cfg.max_seq, cfg.d_head
    ng = cfg.n_groups
    w_inputs = [
        {"name": f"w.{n}", "dtype": "f32", "shape": list(param_shapes(cfg)[n])}
        for n in param_names(cfg)
    ]
    f32 = lambda name, shape: {"name": name, "dtype": "f32", "shape": list(shape)}
    i64 = lambda name, shape: {"name": name, "dtype": "i64", "shape": list(shape)}
    if kind == "prefill":
        ins = w_inputs + [i64("tokens", (b, s)), f32("len_mask", (b, s))]
        outs = ["logits", "k", "v", "attn_acc", "qmax", "kmax"]
    elif kind == "decode_mikv":
        ins = w_inputs + [
            i64("token", (b,)), i64("pos", (b,)),
            f32("k_hi", (b, l, h, s, d)), f32("v_hi", (b, l, h, s, d)),
            f32("hi_mask", (b, l, h, s)),
            f32("k_lo_codes", (b, l, h, s, d)),
            f32("k_lo_scale", (b, l, h, s, ng)), f32("k_lo_zero", (b, l, h, s, ng)),
            f32("v_lo_codes", (b, l, h, s, d)),
            f32("v_lo_scale", (b, l, h, s, ng)), f32("v_lo_zero", (b, l, h, s, ng)),
            f32("lo_mask", (b, l, h, s)), f32("inv_b", (b, l, h, d)),
        ]
        outs = ["logits", "k_new", "v_new", "attn_prev", "attn_self"]
    elif kind == "decode_full":
        ins = w_inputs + [
            i64("token", (b,)), i64("pos", (b,)),
            f32("k_full", (b, l, h, s, d)), f32("v_full", (b, l, h, s, d)),
            f32("mask", (b, l, h, s)), i64("oracle_k", ()),
        ]
        outs = ["logits", "k_new", "v_new", "attn_prev", "attn_self"]
    else:
        raise ValueError(kind)
    return ins, outs


def lower_graph(cfg: ModelConfig, kind: str, b: int) -> str:
    ins, _ = graph_io(cfg, kind, b)
    arg_specs = [
        spec(i["shape"], jnp.int64 if i["dtype"] == "i64" else jnp.float32)
        for i in ins
    ]
    nw = len(param_names(cfg))

    if kind == "prefill":
        fn = lambda *a: prefill(cfg, a[:nw], *a[nw:], use_pallas=True)
    elif kind == "decode_mikv":
        fn = lambda *a: decode_mikv(cfg, a[:nw], *a[nw:], use_pallas=True)
    elif kind == "decode_full":
        fn = lambda *a: decode_full(cfg, a[:nw], *a[nw:])
    else:
        raise ValueError(kind)

    lowered = jax.jit(fn).lower(*arg_specs)
    return to_hlo_text(lowered)


def lower_quant(cfg: ModelConfig, bits: int) -> str:
    """Bulk quantization graph: [max_seq, d_head] → codes/scales/zeros."""
    fn = lambda x: quantize_block(x, bits=bits, group=cfg.quant_group, use_pallas=True)
    lowered = jax.jit(fn).lower(spec((cfg.max_seq, cfg.d_head)))
    return to_hlo_text(lowered)


# ----------------------------------------------------------------------
# Golden parity fixtures (rust integration tests replay these)
# ----------------------------------------------------------------------


def make_goldens(cfg: ModelConfig, params: dict, b: int, seed: int = 1234):
    """Run prefill + one decode_mikv + one decode_full step in python and
    record all inputs/outputs for bit-parity replay from rust."""
    rng = np.random.default_rng(seed)
    l, h, s, d = cfg.n_layers, cfg.n_kv_heads, cfg.max_seq, cfg.d_head
    ng = cfg.n_groups
    flat = params_to_list(cfg, params)

    out: dict[str, np.ndarray] = {}

    # ---- prefill ----
    samples = [corpus.gen_lineret(rng, 4) for _ in range(b)]
    tokens, len_mask, _ = corpus.batch_samples(samples, s)
    pf = jax.jit(lambda *a: prefill(cfg, a[:len(flat)], *a[len(flat):], use_pallas=True))
    logits, k, v, acc, qmax, kmax = pf(*flat, jnp.asarray(tokens), jnp.asarray(len_mask))
    out["prefill.in.tokens"] = tokens
    out["prefill.in.len_mask"] = len_mask
    for name, val in [
        ("logits", logits), ("k", k), ("v", v),
        ("attn_acc", acc), ("qmax", qmax), ("kmax", kmax),
    ]:
        out[f"prefill.out.{name}"] = np.asarray(val)

    # ---- decode_mikv with a synthetic cache state ----
    f = lambda *shape: rng.standard_normal(shape).astype(np.float32)
    hi_mask = (rng.random((b, l, h, s)) < 0.3).astype(np.float32)
    lo_mask = ((rng.random((b, l, h, s)) < 0.5) * (1 - hi_mask)).astype(np.float32)
    din = {
        "token": rng.integers(1, cfg.vocab, size=(b,)).astype(np.int64),
        "pos": np.full((b,), s // 2, dtype=np.int64),
        "k_hi": f(b, l, h, s, d), "v_hi": f(b, l, h, s, d),
        "hi_mask": hi_mask,
        "k_lo_codes": rng.integers(0, 16, size=(b, l, h, s, d)).astype(np.float32),
        "k_lo_scale": (0.01 + rng.random((b, l, h, s, ng))).astype(np.float32),
        "k_lo_zero": f(b, l, h, s, ng),
        "v_lo_codes": rng.integers(0, 16, size=(b, l, h, s, d)).astype(np.float32),
        "v_lo_scale": (0.01 + rng.random((b, l, h, s, ng))).astype(np.float32),
        "v_lo_zero": f(b, l, h, s, ng),
        "lo_mask": lo_mask,
        "inv_b": (0.5 + rng.random((b, l, h, d))).astype(np.float32),
    }
    dm = jax.jit(lambda *a: decode_mikv(cfg, a[:len(flat)], *a[len(flat):], use_pallas=True))
    ins_order = ["token", "pos", "k_hi", "v_hi", "hi_mask", "k_lo_codes",
                 "k_lo_scale", "k_lo_zero", "v_lo_codes", "v_lo_scale",
                 "v_lo_zero", "lo_mask", "inv_b"]
    res = dm(*flat, *[jnp.asarray(din[n]) for n in ins_order])
    for n in ins_order:
        out[f"decode_mikv.in.{n}"] = din[n]
    for name, val in zip(["logits", "k_new", "v_new", "attn_prev", "attn_self"], res):
        out[f"decode_mikv.out.{name}"] = np.asarray(val)

    # ---- decode_full with oracle ----
    mask = np.zeros((b, l, h, s), dtype=np.float32)
    mask[:, :, :, : s // 2] = 1.0
    fin = {
        "token": din["token"], "pos": din["pos"],
        "k_full": f(b, l, h, s, d), "v_full": f(b, l, h, s, d),
        "mask": mask, "oracle_k": np.asarray(8, dtype=np.int64),
    }
    df = jax.jit(lambda *a: decode_full(cfg, a[:len(flat)], *a[len(flat):]))
    fins_order = ["token", "pos", "k_full", "v_full", "mask", "oracle_k"]
    res = df(*flat, *[jnp.asarray(fin[n]) for n in fins_order])
    for n in fins_order:
        out[f"decode_full.in.{n}"] = fin[n]
    for name, val in zip(["logits", "k_new", "v_new", "attn_prev", "attn_self"], res):
        out[f"decode_full.out.{name}"] = np.asarray(val)

    return out


# ----------------------------------------------------------------------
# Main
# ----------------------------------------------------------------------


def corpus_constants() -> dict:
    return {
        "PAD": corpus.PAD, "BOS": corpus.BOS, "REC": corpus.REC,
        "SEP": corpus.SEP, "QUERY": corpus.QUERY, "ANS": corpus.ANS,
        "EOS": corpus.EOS, "HOP": corpus.HOP,
        "KEY_BASE": corpus.KEY_BASE, "KEY_N": corpus.KEY_N,
        "VAL_BASE": corpus.VAL_BASE, "VAL_N": corpus.VAL_N,
        "FILL_BASE": corpus.FILL_BASE, "FILL_N": corpus.FILL_N,
        "PAT_BASE": corpus.PAT_BASE, "PAT_N": corpus.PAT_N,
        "VOCAB": corpus.VOCAB, "KEY_TOKS": corpus.KEY_TOKS,
        "VAL_TOKS": corpus.VAL_TOKS,
    }


def get_or_train_weights(cfg: ModelConfig, out_dir: str, steps: int, log) -> tuple[dict, dict]:
    path = os.path.join(out_dir, f"weights-{cfg.name}.mikv")
    if os.path.exists(path):
        params, meta = load_checkpoint(path)
        if meta.get("train_steps", -1) == steps:
            log(f"  weights cached: {path}")
            return params, meta
    if steps == 0:
        params = init_params(cfg, jax.random.PRNGKey(0))
        meta = {"train_steps": 0, "loss_curve": []}
    else:
        params, curve = train(cfg, steps=steps, log=log)
        meta = {"train_steps": steps, "loss_curve": curve}
    save_checkpoint(path, cfg, {n: np.asarray(a) for n, a in params.items()}, meta)
    log(f"  wrote {path}")
    return params, meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="cfg-tiny,cfg-s,cfg-s-gqa")
    ap.add_argument("--steps", type=int, default=-1, help="override train steps")
    ap.add_argument("--skip-quant", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    log = lambda *a: print(*a, file=sys.stderr, flush=True)
    manifest: dict = {"version": 1, "corpus": corpus_constants(), "models": {}}

    for name in args.models.split(","):
        cfg = CONFIGS[name]
        log(f"[aot] model {name} ({cfg.param_count()/1e6:.2f}M params)")
        steps = args.steps if args.steps >= 0 else TRAIN_STEPS[name]
        params, meta = get_or_train_weights(cfg, args.out, steps, log)

        entry: dict = {
            "config": {
                "vocab": cfg.vocab, "d_model": cfg.d_model,
                "n_layers": cfg.n_layers, "n_q_heads": cfg.n_q_heads,
                "n_kv_heads": cfg.n_kv_heads, "d_head": cfg.d_head,
                "d_ff": cfg.d_ff, "max_seq": cfg.max_seq,
                "rope_theta": cfg.rope_theta, "quant_group": cfg.quant_group,
                "params": cfg.param_count(),
            },
            "weights": f"weights-{cfg.name}.mikv",
            "train_steps": meta.get("train_steps", 0),
            "loss_curve": meta.get("loss_curve", []),
            "param_order": param_names(cfg),
            "graphs": {},
            "quant_graphs": {},
        }

        for b in BATCHES[name]:
            for kind in ["prefill", "decode_mikv", "decode_full"]:
                t0 = time.time()
                text = lower_graph(cfg, kind, b)
                fname = f"{name}-{kind}-b{b}.hlo.txt"
                with open(os.path.join(args.out, fname), "w") as fh:
                    fh.write(text)
                ins, outs = graph_io(cfg, kind, b)
                entry["graphs"][f"{kind}-b{b}"] = {
                    "file": fname, "batch": b, "inputs": ins, "outputs": outs,
                }
                log(f"  lowered {fname} ({len(text)/1e6:.1f}MB, {time.time()-t0:.1f}s)")

        if not args.skip_quant:
            for bits in QUANT_BITS:
                text = lower_quant(cfg, bits)
                fname = f"{name}-quant{bits}.hlo.txt"
                with open(os.path.join(args.out, fname), "w") as fh:
                    fh.write(text)
                entry["quant_graphs"][str(bits)] = {
                    "file": fname,
                    "rows": cfg.max_seq,
                    "dim": cfg.d_head,
                    "group": cfg.quant_group,
                }

        # Golden fixtures only for the smallest config (fast + sufficient).
        if name == "cfg-tiny":
            for b in BATCHES[name]:
                gold = make_goldens(cfg, params, b)
                gname = f"golden-{name}-b{b}.mikv"
                write_tensors(
                    os.path.join(args.out, gname), gold,
                    {"model": name, "batch": b, "seed": 1234},
                )
                entry.setdefault("goldens", {})[str(b)] = gname
                log(f"  wrote {gname}")

        manifest["models"][name] = entry

    with open(os.path.join(args.out, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    log(f"[aot] wrote {args.out}/manifest.json")


if __name__ == "__main__":
    main()
