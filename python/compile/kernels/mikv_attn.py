"""L1 Pallas kernel: fused mixed-precision decode attention.

This is the MiKV hot spot — the TPU adaptation of the paper's §3.4 GPU
weight-only-quant GEMV trick. One grid step processes one batch lane with
ALL of its KV heads resident (block `[H, …]`):

* the lo-tier K/V arrive as **integer codes + per-group scale/zero**, and
  are dequantized *inside the kernel's VMEM block* — so in a real TPU
  deployment the HBM→VMEM traffic is the compressed representation, the
  exact analogue of the paper's "apply weight-only quantization kernels
  instead of batch-GEMV";
* the channel balancer inverse (`1/b`) is fused into the dequantized keys
  as a free VPU element-wise multiply (paper eq. 3–4, runtime-inverse
  formulation — queries stay untouched so hi-tier scores are bit-identical
  to the unbalanced path);
* hi and lo tiers are processed as two homogeneous batched-matmul loops
  feeding one shared softmax — the paper's permutation-invariance argument
  (§3.4) realized as tier grouping instead of per-token branching.

Grid: `(B,)`. §Perf iteration #1 (EXPERIMENTS.md): the original grid was
`(B, H_kv)`, one plane per step; under interpret mode the grid lowers to a
sequential HLO loop, so per-head steps serialized 8–32 kernel bodies per
layer. Folding heads into the block vectorizes them (einsums over the
`h` axis) at a VMEM cost of H× per step — for the repro config that is
8 × 51 KB ≈ 0.4 MB, still ≪ 16 MB VMEM (DESIGN.md §Perf-estimates).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO with identical numerics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF, mikv_attention_ref


def _mikv_attn_kernel(
    # inputs (leading 1 block dim from the batch grid)
    q_ref,        # [1, H, G, D]
    k_new_ref,    # [1, H, D]
    v_new_ref,    # [1, H, D]
    k_hi_ref,     # [1, H, S, D]
    v_hi_ref,     # [1, H, S, D]
    hi_mask_ref,  # [1, H, S]
    k_lo_c_ref,   # [1, H, S, D]
    k_lo_s_ref,   # [1, H, S, NG]
    k_lo_z_ref,   # [1, H, S, NG]
    v_lo_c_ref,
    v_lo_s_ref,
    v_lo_z_ref,
    lo_mask_ref,  # [1, H, S]
    inv_b_ref,    # [1, H, D]
    # outputs
    out_ref,       # [1, H, G, D]
    attn_prev_ref, # [1, H, S]
    attn_self_ref, # [1, H, G]  (per-q-head self prob; summed host-side)
    *,
    group: int,
):
    q = q_ref[...]          # [B, H, G, D]
    k_new = k_new_ref[...]  # [B, H, D]
    v_new = v_new_ref[...]
    k_hi = k_hi_ref[...]    # [B, H, S, D]
    v_hi = v_hi_ref[...]
    hi_mask = hi_mask_ref[...]  # [B, H, S]
    lo_mask = lo_mask_ref[...]
    inv_b = inv_b_ref[...]  # [B, H, D]

    b, h, s, d = k_hi.shape
    ng = d // group
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    # --- in-VMEM dequantization of the retained tier (codes → floats) ---
    def dequant(c_ref, s_ref, z_ref):
        codes = c_ref[...].reshape(b, h, s, ng, group)
        return (s_ref[...][..., None] * codes + z_ref[...][..., None]).reshape(b, h, s, d)

    k_lo = dequant(k_lo_c_ref, k_lo_s_ref, k_lo_z_ref) * inv_b[:, :, None, :]
    v_lo = dequant(v_lo_c_ref, v_lo_s_ref, v_lo_z_ref)

    # --- two homogeneous tier loops → one shared softmax (batched B×H) ---
    s_hi = jnp.where(
        hi_mask[:, :, None, :] > 0,
        jnp.einsum("bhgd,bhsd->bhgs", q, k_hi) * scale,
        NEG_INF,
    )
    s_lo = jnp.where(
        lo_mask[:, :, None, :] > 0,
        jnp.einsum("bhgd,bhsd->bhgs", q, k_lo) * scale,
        NEG_INF,
    )
    s_self = jnp.einsum("bhgd,bhd->bhg", q, k_new) * scale  # [B, H, G]

    logits = jnp.concatenate([s_hi, s_lo, s_self[..., None]], axis=3)
    m = logits.max(axis=3, keepdims=True)
    e = jnp.exp(logits - m)
    p = e / e.sum(axis=3, keepdims=True)

    p_hi, p_lo, p_self = p[..., :s], p[..., s : 2 * s], p[..., 2 * s]
    out_ref[...] = (
        jnp.einsum("bhgs,bhsd->bhgd", p_hi, v_hi)
        + jnp.einsum("bhgs,bhsd->bhgd", p_lo, v_lo)
        + p_self[..., None] * v_new[:, :, None, :]
    )
    attn_prev_ref[...] = (p_hi + p_lo).sum(axis=2)
    attn_self_ref[...] = p_self


def mikv_attention(
    q,          # [B, H, G, D]
    k_new,      # [B, H, D]
    v_new,      # [B, H, D]
    k_hi,       # [B, H, S, D]
    v_hi,
    hi_mask,    # [B, H, S]
    k_lo_codes, # [B, H, S, D]
    k_lo_scale, # [B, H, S, NG]
    k_lo_zero,
    v_lo_codes,
    v_lo_scale,
    v_lo_zero,
    lo_mask,    # [B, H, S]
    inv_b,      # [B, H, D]
    *,
    group: int,
    use_pallas: bool = True,
):
    """Batched fused mixed-precision decode attention.

    Returns (out [B, H, G, D], attn_prev [B, H, S], attn_self [B, H]).
    """
    b, h, g, d = q.shape
    s = k_hi.shape[2]
    ng = d // group

    if not use_pallas:
        fn = functools.partial(_ref_plane, group=group)
        fn = jax.vmap(jax.vmap(fn))
        out, attn_prev, attn_self = fn(
            q, k_new, v_new, k_hi, v_hi, hi_mask,
            k_lo_codes, k_lo_scale, k_lo_zero,
            v_lo_codes, v_lo_scale, v_lo_zero, lo_mask, inv_b,
        )
        return out, attn_prev, attn_self

    # §Perf iteration #2: fold the batch into the block as well — a single
    # kernel invocation per decode step (grid (1,)). VMEM: B×H×~51 KB, still
    # far under budget for the repro configs (DESIGN.md §Perf-estimates).
    whole = lambda *shp: pl.BlockSpec(shp, lambda _: (0,) * len(shp))
    out, attn_prev, attn_self_per_head = pl.pallas_call(
        functools.partial(_mikv_attn_kernel, group=group),
        grid=(1,),
        in_specs=[
            whole(b, h, g, d),   # q
            whole(b, h, d),      # k_new
            whole(b, h, d),      # v_new
            whole(b, h, s, d),   # k_hi
            whole(b, h, s, d),   # v_hi
            whole(b, h, s),      # hi_mask
            whole(b, h, s, d),   # k_lo_codes
            whole(b, h, s, ng),  # k_lo_scale
            whole(b, h, s, ng),  # k_lo_zero
            whole(b, h, s, d),   # v_lo_codes
            whole(b, h, s, ng),  # v_lo_scale
            whole(b, h, s, ng),  # v_lo_zero
            whole(b, h, s),      # lo_mask
            whole(b, h, d),      # inv_b
        ],
        out_specs=[whole(b, h, g, d), whole(b, h, s), whole(b, h, g)],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, s), jnp.float32),
            jax.ShapeDtypeStruct((b, h, g), jnp.float32),
        ],
        interpret=True,
    )(
        q, k_new, v_new, k_hi, v_hi, hi_mask,
        k_lo_codes, k_lo_scale, k_lo_zero,
        v_lo_codes, v_lo_scale, v_lo_zero, lo_mask, inv_b,
    )
    return out, attn_prev, attn_self_per_head.sum(axis=-1)


def _ref_plane(
    q, k_new, v_new, k_hi, v_hi, hi_mask,
    k_lo_codes, k_lo_scale, k_lo_zero,
    v_lo_codes, v_lo_scale, v_lo_zero, lo_mask, inv_b,
    *, group: int,
):
    return mikv_attention_ref(
        q, k_new, v_new, k_hi, v_hi, hi_mask,
        k_lo_codes, k_lo_scale, k_lo_zero,
        v_lo_codes, v_lo_scale, v_lo_zero, lo_mask, inv_b, group=group,
    )
