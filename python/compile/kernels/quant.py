"""L1 Pallas kernel: per-token asymmetric quantization (paper eq. 1).

Used by the optional `quant_block` artifact — the bulk prefill-ingestion
path where the rust engine offloads quantization of a whole `[N, D]` block
of demoted KV vectors to the accelerator instead of quantizing token by
token on the host (engine flag `quant_engine = hlo | native`; the ablation
bench compares both).

Grid: 1-D over row tiles of `block_n` tokens. Each grid step loads a
`[block_n, D]` tile into VMEM, computes per-group min/max (VPU reduction),
derives scale/zero, and emits integer codes — all without touching HBM
again. FP16 metadata rounding is fused (astype round-trip).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, codes_ref, scale_ref, zero_ref, *, bits: int, group: int, f16_meta: bool):
    x = x_ref[...]  # [block_n, D]
    n, d = x.shape
    ng = d // group
    levels = (1 << bits) - 1

    xg = x.reshape(n, ng, group)
    lo = xg.min(axis=-1)
    hi = xg.max(axis=-1)
    scale = (hi - lo) / levels
    zero = lo
    if f16_meta:
        scale = scale.astype(jnp.float16).astype(jnp.float32)
        zero = zero.astype(jnp.float16).astype(jnp.float32)
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.round((xg - zero[:, :, None]) / safe[:, :, None]), 0, levels)
    codes = jnp.where(scale[:, :, None] > 0, codes, 0.0)

    codes_ref[...] = codes.reshape(n, d)
    scale_ref[...] = scale
    zero_ref[...] = zero


def quantize_block(
    x,  # [N, D]
    *,
    bits: int,
    group: int,
    f16_meta: bool = True,
    block_n: int = 64,
    use_pallas: bool = True,
):
    """Quantize a block of token vectors. Returns (codes [N, D] float-held
    integers, scales [N, NG], zeros [N, NG])."""
    n, d = x.shape
    assert d % group == 0
    ng = d // group

    if not use_pallas:
        from .ref import quantize_ref

        return quantize_ref(x, bits, group, f16_meta)

    block_n = min(block_n, n)
    # pad rows to a multiple of block_n
    n_pad = (-n) % block_n
    if n_pad:
        x = jnp.concatenate([x, jnp.zeros((n_pad, d), x.dtype)], axis=0)
    nt = x.shape[0] // block_n

    codes, scales, zeros = pl.pallas_call(
        functools.partial(_quant_kernel, bits=bits, group=group, f16_meta=f16_meta),
        grid=(nt,),
        in_specs=[pl.BlockSpec((block_n, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, ng), lambda i: (i, 0)),
            pl.BlockSpec((block_n, ng), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((x.shape[0], d), jnp.float32),
            jax.ShapeDtypeStruct((x.shape[0], ng), jnp.float32),
            jax.ShapeDtypeStruct((x.shape[0], ng), jnp.float32),
        ],
        interpret=True,
    )(x)
    return codes[:n], scales[:n], zeros[:n]


def _dequant_kernel(c_ref, s_ref, z_ref, out_ref, *, group: int):
    c = c_ref[...]
    n, d = c.shape
    ng = d // group
    cg = c.reshape(n, ng, group)
    out_ref[...] = (s_ref[...][:, :, None] * cg + z_ref[...][:, :, None]).reshape(n, d)


def dequantize_block(codes, scales, zeros, *, group: int, block_n: int = 64, use_pallas: bool = True):
    """Inverse of `quantize_block`."""
    n, d = codes.shape
    ng = d // group
    if not use_pallas:
        from .ref import dequantize_ref

        return dequantize_ref(codes, scales, zeros, group)

    block_n = min(block_n, n)
    n_pad = (-n) % block_n
    if n_pad:
        codes = jnp.concatenate([codes, jnp.zeros((n_pad, d), codes.dtype)], axis=0)
        scales = jnp.concatenate([scales, jnp.zeros((n_pad, ng), scales.dtype)], axis=0)
        zeros = jnp.concatenate([zeros, jnp.zeros((n_pad, ng), zeros.dtype)], axis=0)
    nt = codes.shape[0] // block_n

    out = pl.pallas_call(
        functools.partial(_dequant_kernel, group=group),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, ng), lambda i: (i, 0)),
            pl.BlockSpec((block_n, ng), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((codes.shape[0], d), jnp.float32),
        interpret=True,
    )(codes, scales, zeros)
    return out[:n]
