"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness anchors of layer 1: each kernel in this package
must match its `*_ref` twin to float tolerance under pytest/hypothesis
sweeps (`python/tests/test_kernels.py`). They are also used directly by the
L2 model when `use_pallas=False`, which gives an independent end-to-end
check that the kernels compose correctly.

Shape conventions (one attention *plane* = one KV head group):
    G      query heads per KV head (GQA group size; G = Hq // Hkv)
    S      max sequence slots (padded; masks select live slots)
    D      head dim
    NG     scale/zero groups per token (= D / group)
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


# ----------------------------------------------------------------------
# Per-token asymmetric quantization (paper eq. 1)
# ----------------------------------------------------------------------


def quantize_ref(x, bits: int, group: int, f16_meta: bool = True):
    """Quantize the trailing dim of `x` in groups of `group` channels.

    Returns (codes, scales, zeros): codes are float-held integers with the
    same shape as `x`; scales/zeros have trailing dim `x.shape[-1] // group`.
    """
    d = x.shape[-1]
    assert d % group == 0, f"group {group} must divide dim {d}"
    levels = (1 << bits) - 1
    xg = x.reshape(*x.shape[:-1], d // group, group)
    lo = xg.min(axis=-1, keepdims=True)
    hi = xg.max(axis=-1, keepdims=True)
    scale = (hi - lo) / levels
    zero = lo
    if f16_meta:
        scale = scale.astype(jnp.float16).astype(jnp.float32)
        zero = zero.astype(jnp.float16).astype(jnp.float32)
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.round((xg - zero) / safe), 0, levels)
    codes = jnp.where(scale > 0, codes, 0.0)
    return (
        codes.reshape(x.shape),
        scale.squeeze(-1),
        zero.squeeze(-1),
    )


def dequantize_ref(codes, scales, zeros, group: int):
    """Inverse of `quantize_ref`: `x̂ = α·code + β` per group."""
    d = codes.shape[-1]
    cg = codes.reshape(*codes.shape[:-1], d // group, group)
    out = scales[..., None] * cg + zeros[..., None]
    return out.reshape(codes.shape)


# ----------------------------------------------------------------------
# Rotary positional embeddings (half-split convention)
# ----------------------------------------------------------------------


def rope_angles(positions, d: int, theta: float = 10000.0):
    """cos/sin tables for `positions` (any shape) → shape (*pos, d/2)."""
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def rope_ref(x, cos, sin):
    """Apply RoPE to the trailing dim of `x` (split-half rotation).

    `cos`/`sin` broadcast against `x[..., : d/2]`.
    """
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ----------------------------------------------------------------------
# Fused mixed-precision decode attention (the MiKV hot spot)
# ----------------------------------------------------------------------


def mikv_attention_ref(
    q,            # [G, D]   query heads of one KV group (RoPE applied)
    k_new, v_new, # [D]      current token's K/V for this KV head
    k_hi, v_hi,   # [S, D]   hi-tier cache (fp values)
    hi_mask,      # [S]      1.0 where slot is hi-resident
    k_lo_codes,   # [S, D]   lo-tier codes (float-held integers)
    k_lo_scale, k_lo_zero,   # [S, NG]
    v_lo_codes, v_lo_scale, v_lo_zero,
    lo_mask,      # [S]
    inv_b,        # [D]      1/balancer; dequantized lo keys are scaled by it
    group: int,
):
    """One decode step of mixed-precision attention for one plane.

    Returns (out [G, D], attn_prev [S], attn_self []): `attn_prev` is the
    per-slot attention mass summed over the group's query heads (hi and lo
    tiers are disjoint, so their contributions add), feeding the H2O
    importance accumulator on the rust side.
    """
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    k_lo = dequantize_ref(k_lo_codes, k_lo_scale, k_lo_zero, group) * inv_b[None, :]
    v_lo = dequantize_ref(v_lo_codes, v_lo_scale, v_lo_zero, group)

    s_hi = jnp.where(hi_mask[None, :] > 0, (q @ k_hi.T) * scale, NEG_INF)
    s_lo = jnp.where(lo_mask[None, :] > 0, (q @ k_lo.T) * scale, NEG_INF)
    s_self = (q @ k_new) * scale  # [G]

    logits = jnp.concatenate([s_hi, s_lo, s_self[:, None]], axis=1)  # [G, 2S+1]
    m = logits.max(axis=1, keepdims=True)
    e = jnp.exp(logits - m)
    p = e / e.sum(axis=1, keepdims=True)

    s = k_hi.shape[0]
    p_hi, p_lo, p_self = p[:, :s], p[:, s : 2 * s], p[:, 2 * s]
    out = p_hi @ v_hi + p_lo @ v_lo + p_self[:, None] * v_new[None, :]
    attn_prev = (p_hi + p_lo).sum(axis=0)
    attn_self = p_self.sum()
    return out, attn_prev, attn_self


# ----------------------------------------------------------------------
# Full-cache decode attention with post-softmax oracle top-k (Fig. 3b)
# ----------------------------------------------------------------------


def oracle_attention_ref(
    q,            # [G, D]
    k_new, v_new, # [D]
    k, v,         # [S, D] full-precision cache
    mask,         # [S]
    oracle_k,     # scalar int: keep top-k attention weights (k > S+1 ⇒ all)
):
    """Full-cache attention; post-softmax top-k sparsification + renorm.

    This is the paper's oracle eviction: the attention map is computed with
    the FULL cache first, then top-k sparsity is imposed post-attention —
    a proxy upper bound where future importance is predicted perfectly.
    """
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    s_prev = jnp.where(mask[None, :] > 0, (q @ k.T) * scale, NEG_INF)
    s_self = (q @ k_new) * scale
    logits = jnp.concatenate([s_prev, s_self[:, None]], axis=1)  # [G, S+1]
    m = logits.max(axis=1, keepdims=True)
    e = jnp.exp(logits - m)
    p = e / e.sum(axis=1, keepdims=True)

    # Keep the top-k probabilities per head, renormalize.
    n = logits.shape[1]
    sorted_p = jnp.sort(p, axis=1)[:, ::-1]  # descending
    idx = jnp.clip(oracle_k - 1, 0, n - 1)
    thresh = sorted_p[:, idx][:, None]
    keep = p >= thresh
    p = jnp.where(keep, p, 0.0)
    p = p / p.sum(axis=1, keepdims=True)

    s = k.shape[0]
    p_prev, p_self = p[:, :s], p[:, s]
    out = p_prev @ v + p_self[:, None] * v_new[None, :]
    attn_prev = p_prev.sum(axis=0)
    attn_self = p_self.sum()
    return out, attn_prev, attn_self


# ----------------------------------------------------------------------
# Prefill causal attention with importance column-sums
# ----------------------------------------------------------------------


def prefill_attention_ref(
    q,         # [G, S, D]  query heads of one KV group (RoPE applied)
    k, v,      # [S, D]
    len_mask,  # [S] 1.0 for live positions
):
    """Causal attention over a full prompt for one plane.

    Returns (out [G, S, D], attn_acc [S], qmax [D], kmax [D]):
    `attn_acc[s]` is the total attention mass key `s` received from all
    live queries in the group (H2O seed); qmax/kmax are per-channel absolute
    maxima over live positions (balancer seed, paper eq. 2).
    """
    g, s, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    scores = jnp.einsum("gqd,kd->gqk", q, k) * scale
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    valid = causal[None, :, :] & (len_mask[None, None, :] > 0)
    scores = jnp.where(valid, scores, NEG_INF)
    m = scores.max(axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / e.sum(axis=-1, keepdims=True)  # [G, S, S]
    out = jnp.einsum("gqk,kd->gqd", p, v)

    # Column sums over live query rows only.
    qlive = len_mask[None, :, None]  # [1, S, 1]
    attn_acc = (p * qlive).sum(axis=(0, 1))  # [S]

    qmax = jnp.abs(q * len_mask[None, :, None]).max(axis=(0, 1))  # [D]
    kmax = jnp.abs(k * len_mask[:, None]).max(axis=0)  # [D]
    return out, attn_acc, qmax, kmax
