"""L1 Pallas kernel: prefill causal attention with importance side-outputs.

Beyond the attention output itself, the prefill pass must produce the two
statistics MiKV's cache manager needs (paper §3.1–3.2):

* `attn_acc[s]` — total attention mass key `s` received from all live
  queries (the H2O heavy-hitter seed);
* `qmax` / `kmax` — per-channel absolute maxima of the (RoPE'd) queries and
  keys over live positions, from which the rust side computes the channel
  balancer `b = sqrt(qmax/kmax)` (paper eq. 2).

Grid: `(B, H_kv)`, one plane per step; each plane's `[G, S, S]` score tile
lives in VMEM (see DESIGN.md §Hardware-Adaptation for the footprint table;
query-block tiling is the documented scale-up path for long prompts).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF, prefill_attention_ref


def _prefill_kernel(
    q_ref,    # [1, 1, G, S, D]
    k_ref,    # [1, 1, S, D]
    v_ref,    # [1, 1, S, D]
    mask_ref, # [1, 1, S]
    out_ref,  # [1, 1, G, S, D]
    acc_ref,  # [1, 1, S]
    qmax_ref, # [1, 1, D]
    kmax_ref, # [1, 1, D]
):
    q = q_ref[0, 0]        # [G, S, D]
    k = k_ref[0, 0]        # [S, D]
    v = v_ref[0, 0]
    len_mask = mask_ref[0, 0]  # [S]

    g, s, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    scores = jnp.einsum("gqd,kd->gqk", q, k) * scale
    row = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    causal = row >= col
    valid = causal[None, :, :] & (len_mask[None, None, :] > 0)
    scores = jnp.where(valid, scores, NEG_INF)
    m = scores.max(axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / e.sum(axis=-1, keepdims=True)

    out_ref[0, 0] = jnp.einsum("gqk,kd->gqd", p, v)
    acc_ref[0, 0] = (p * len_mask[None, :, None]).sum(axis=(0, 1))
    qmax_ref[0, 0] = jnp.abs(q * len_mask[None, :, None]).max(axis=(0, 1))
    kmax_ref[0, 0] = jnp.abs(k * len_mask[:, None]).max(axis=0)


def prefill_attention(
    q,         # [B, H, G, S, D]
    k,         # [B, H, S, D]
    v,
    len_mask,  # [B, S]
    *,
    use_pallas: bool = True,
):
    """Batched prefill attention.

    Returns (out [B, H, G, S, D], attn_acc [B, H, S], qmax [B, H, D],
    kmax [B, H, D]).
    """
    b, h, g, s, d = q.shape

    if not use_pallas:
        fn = jax.vmap(  # over B
            jax.vmap(prefill_attention_ref, in_axes=(0, 0, 0, None)),  # over H
            in_axes=(0, 0, 0, 0),
        )
        return fn(q, k, v, len_mask)

    # broadcast the per-batch mask to planes so each grid step sees [S]
    mask_bh = jnp.broadcast_to(len_mask[:, None, :], (b, h, s))

    plane = lambda *shp: pl.BlockSpec((1, 1) + shp, lambda bi, hi: (bi, hi) + (0,) * len(shp))
    out, acc, qmax, kmax = pl.pallas_call(
        _prefill_kernel,
        grid=(b, h),
        in_specs=[
            plane(g, s, d),
            plane(s, d),
            plane(s, d),
            plane(s),
        ],
        out_specs=[plane(g, s, d), plane(s), plane(d), plane(d)],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, g, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, s), jnp.float32),
            jax.ShapeDtypeStruct((b, h, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, d), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, mask_bh)
    return out, acc, qmax, kmax
