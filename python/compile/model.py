"""L2: the decoder-only transformer (JAX), calling the L1 Pallas kernels.

Three graphs are AOT-lowered per model/batch configuration (see `aot.py`):

* `prefill`   — full prompt pass; returns logits, per-layer K/V, the H2O
                attention accumulator seed, and the balancer q/k maxima.
* `decode_mikv` — one token step against the mixed-precision cache
                (hi fp tensors + lo codes/scales/zeros + masks + 1/b),
                attention fused in `kernels.mikv_attn`.
* `decode_full` — one token step against a full-precision cache with the
                post-softmax oracle top-k input (paper Fig. 3b); `oracle_k
                >= S+1` makes it the exact uncompressed baseline.

Weights are **runtime inputs**, not baked constants: the rust engine
uploads them once as device-resident PJRT buffers and reuses them every
step. Parameter order is fixed by `param_names()` and recorded in the
artifact manifest.

All tensor layouts are batch-outermost and plane-major —
`[B, L, H_kv, S, D]` — so one session's cache block is contiguous on the
rust side (single memcpy per input per step).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import mikv_attn, prefill_attn
from .kernels import ref as kref


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_q_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    max_seq: int
    rope_theta: float = 10000.0
    # quant group size for the lo tier (paper: half the head dim, so a
    # group never straddles the two RoPE-rotated halves)
    quant_group: int = field(default=0)

    def __post_init__(self):
        assert self.n_q_heads % self.n_kv_heads == 0
        if self.quant_group == 0:
            object.__setattr__(self, "quant_group", max(1, self.d_head // 2))

    @property
    def gqa_group(self) -> int:
        return self.n_q_heads // self.n_kv_heads

    @property
    def n_groups(self) -> int:
        """Scale/zero groups per token per head."""
        return self.d_head // self.quant_group

    def param_count(self) -> int:
        e, f, v = self.d_model, self.d_ff, self.vocab
        hq = self.n_q_heads * self.d_head
        hk = self.n_kv_heads * self.d_head
        per_layer = 2 * e + e * hq + 2 * e * hk + hq * e + e * f + f * e
        return v * e + self.n_layers * per_layer + e + e * v


# Registry of reproduction configs (see DESIGN.md §Model).
CONFIGS = {
    "cfg-tiny": ModelConfig(
        # vocab matches the corpus (512): out-of-range target ids make
        # jnp gathers return NaN silently — every config must cover VOCAB.
        name="cfg-tiny", vocab=512, d_model=64, n_layers=2, n_q_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, max_seq=48,
    ),
    "cfg-s": ModelConfig(
        name="cfg-s", vocab=512, d_model=256, n_layers=4, n_q_heads=8,
        n_kv_heads=8, d_head=32, d_ff=1024, max_seq=320,
    ),
    "cfg-s-gqa": ModelConfig(
        name="cfg-s-gqa", vocab=512, d_model=256, n_layers=4, n_q_heads=8,
        n_kv_heads=2, d_head=32, d_ff=1024, max_seq=320,
    ),
    "cfg-m": ModelConfig(
        name="cfg-m", vocab=512, d_model=512, n_layers=6, n_q_heads=8,
        n_kv_heads=8, d_head=64, d_ff=2048, max_seq=384,
    ),
}


# ----------------------------------------------------------------------
# Parameters
# ----------------------------------------------------------------------


def param_names(cfg: ModelConfig) -> list[str]:
    """Canonical flat parameter order (shared with the rust runtime)."""
    names = ["embed"]
    for i in range(cfg.n_layers):
        names += [
            f"l{i}.ln1", f"l{i}.wq", f"l{i}.wk", f"l{i}.wv", f"l{i}.wo",
            f"l{i}.ln2", f"l{i}.w1", f"l{i}.w2",
        ]
    names += ["lnf", "unembed"]
    return names


def param_shapes(cfg: ModelConfig) -> dict[str, tuple[int, ...]]:
    e, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    hq = cfg.n_q_heads * cfg.d_head
    hk = cfg.n_kv_heads * cfg.d_head
    shapes: dict[str, tuple[int, ...]] = {"embed": (v, e)}
    for i in range(cfg.n_layers):
        shapes.update({
            f"l{i}.ln1": (e,), f"l{i}.wq": (e, hq), f"l{i}.wk": (e, hk),
            f"l{i}.wv": (e, hk), f"l{i}.wo": (hq, e), f"l{i}.ln2": (e,),
            f"l{i}.w1": (e, f), f"l{i}.w2": (f, e),
        })
    shapes.update({"lnf": (e,), "unembed": (e, v)})
    return shapes


def init_params(cfg: ModelConfig, key) -> dict[str, jax.Array]:
    """He-style init; ln scales at 1."""
    shapes = param_shapes(cfg)
    params = {}
    for name, shape in shapes.items():
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2")) or name == "lnf":
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0]
            params[name] = jax.random.normal(sub, shape, jnp.float32) * (fan_in ** -0.5)
    return params


def params_to_list(cfg: ModelConfig, params: dict) -> list[jax.Array]:
    return [params[n] for n in param_names(cfg)]


def params_from_list(cfg: ModelConfig, flat: list) -> dict:
    return dict(zip(param_names(cfg), flat))


# ----------------------------------------------------------------------
# Building blocks
# ----------------------------------------------------------------------


def rmsnorm(x, g, eps: float = 1e-5):
    return x * jax.lax.rsqrt((x * x).mean(axis=-1, keepdims=True) + eps) * g


def _qkv(cfg: ModelConfig, p: dict, i: int, x):
    """Project x [..., E] to q [..., Hq, D], k/v [..., Hkv, D]."""
    q = (x @ p[f"l{i}.wq"]).reshape(*x.shape[:-1], cfg.n_q_heads, cfg.d_head)
    k = (x @ p[f"l{i}.wk"]).reshape(*x.shape[:-1], cfg.n_kv_heads, cfg.d_head)
    v = (x @ p[f"l{i}.wv"]).reshape(*x.shape[:-1], cfg.n_kv_heads, cfg.d_head)
    return q, k, v


def _mlp(p: dict, i: int, x):
    return jax.nn.gelu(x @ p[f"l{i}.w1"]) @ p[f"l{i}.w2"]


# ----------------------------------------------------------------------
# Prefill
# ----------------------------------------------------------------------


def prefill(cfg: ModelConfig, params_flat, tokens, len_mask, *, use_pallas: bool = True):
    """Full prompt pass.

    Args: `tokens` i64[B, S], `len_mask` f32[B, S] (1 = live position).
    Returns (logits f32[B, S, V], k f32[B, L, Hkv, S, D], v …,
    attn_acc f32[B, L, Hkv, S], qmax f32[B, L, Hkv, D], kmax …).
    """
    p = params_from_list(cfg, list(params_flat))
    b, s = tokens.shape
    g = cfg.gqa_group

    x = p["embed"][tokens]  # [B, S, E]
    positions = jnp.arange(s)
    cos, sin = kref.rope_angles(positions, cfg.d_head, cfg.rope_theta)  # [S, D/2]

    ks, vs, accs, qmaxs, kmaxs = [], [], [], [], []
    for i in range(cfg.n_layers):
        h = rmsnorm(x, p[f"l{i}.ln1"])
        q, k, v = _qkv(cfg, p, i, h)  # [B, S, Hq/Hkv, D]
        q = kref.rope_ref(q.transpose(0, 2, 1, 3), cos[None, None], sin[None, None])  # [B, Hq, S, D]
        k = kref.rope_ref(k.transpose(0, 2, 1, 3), cos[None, None], sin[None, None])  # [B, Hkv, S, D]
        v = v.transpose(0, 2, 1, 3)  # [B, Hkv, S, D]
        qg = q.reshape(b, cfg.n_kv_heads, g, s, cfg.d_head)

        out, acc, qmax, kmax = prefill_attn.prefill_attention(
            qg, k, v, len_mask, use_pallas=use_pallas
        )
        out = out.reshape(b, cfg.n_q_heads, s, cfg.d_head).transpose(0, 2, 1, 3)
        x = x + out.reshape(b, s, -1) @ p[f"l{i}.wo"]
        x = x + _mlp(p, i, rmsnorm(x, p[f"l{i}.ln2"]))

        ks.append(k)
        vs.append(v)
        accs.append(acc)
        qmaxs.append(qmax)
        kmaxs.append(kmax)

    logits = rmsnorm(x, p["lnf"]) @ p["unembed"]  # [B, S, V]
    stack = lambda xs: jnp.stack(xs, axis=1)  # → [B, L, ...]
    return (
        logits,
        stack(ks),
        stack(vs),
        stack(accs),
        stack(qmaxs),
        stack(kmaxs),
    )


# ----------------------------------------------------------------------
# Decode against the mixed-precision cache
# ----------------------------------------------------------------------


def decode_mikv(
    cfg: ModelConfig,
    params_flat,
    token,       # i64[B]
    pos,         # i64[B] current position per lane (= cached tokens)
    k_hi,        # f32[B, L, H, S, D]
    v_hi,
    hi_mask,     # f32[B, L, H, S]
    k_lo_codes,  # f32[B, L, H, S, D]
    k_lo_scale,  # f32[B, L, H, S, NG]
    k_lo_zero,
    v_lo_codes,
    v_lo_scale,
    v_lo_zero,
    lo_mask,     # f32[B, L, H, S]
    inv_b,       # f32[B, L, H, D]
    *,
    use_pallas: bool = True,
):
    """One decode step against the MiKV cache.

    Returns (logits f32[B, V], k_new f32[B, L, H, D], v_new …,
    attn_prev f32[B, L, H, S], attn_self f32[B, L, H]).
    """
    p = params_from_list(cfg, list(params_flat))
    b = token.shape[0]
    g = cfg.gqa_group

    x = p["embed"][token]  # [B, E]
    # per-lane positions: lanes of a continuous batch decode at different
    # sequence lengths
    cos, sin = kref.rope_angles(pos.astype(jnp.float32), cfg.d_head, cfg.rope_theta)  # [B, D/2]

    k_news, v_news, attn_prevs, attn_selfs = [], [], [], []
    for i in range(cfg.n_layers):
        h = rmsnorm(x, p[f"l{i}.ln1"])
        q, k, v = _qkv(cfg, p, i, h)  # [B, Hq/Hkv, D]
        q = kref.rope_ref(q, cos[:, None, :], sin[:, None, :])
        k = kref.rope_ref(k, cos[:, None, :], sin[:, None, :])
        qg = q.reshape(b, cfg.n_kv_heads, g, cfg.d_head)

        out, attn_prev, attn_self = mikv_attn.mikv_attention(
            qg, k, v,
            k_hi[:, i], v_hi[:, i], hi_mask[:, i],
            k_lo_codes[:, i], k_lo_scale[:, i], k_lo_zero[:, i],
            v_lo_codes[:, i], v_lo_scale[:, i], v_lo_zero[:, i],
            lo_mask[:, i], inv_b[:, i],
            group=cfg.quant_group, use_pallas=use_pallas,
        )
        x = x + out.reshape(b, -1) @ p[f"l{i}.wo"]
        x = x + _mlp(p, i, rmsnorm(x, p[f"l{i}.ln2"]))

        k_news.append(k)
        v_news.append(v)
        attn_prevs.append(attn_prev)
        attn_selfs.append(attn_self)

    logits = rmsnorm(x, p["lnf"]) @ p["unembed"]  # [B, V]
    stack = lambda xs: jnp.stack(xs, axis=1)
    return logits, stack(k_news), stack(v_news), stack(attn_prevs), stack(attn_selfs)


# ----------------------------------------------------------------------
# Decode against the full cache (exact baseline + oracle eviction)
# ----------------------------------------------------------------------


def decode_full(
    cfg: ModelConfig,
    params_flat,
    token,     # i64[B]
    pos,       # i64[B]
    k_full,    # f32[B, L, H, S, D]
    v_full,
    mask,      # f32[B, L, H, S]
    oracle_k,  # i64[]  keep top-k attention weights; >= S+1 ⇒ exact full
):
    """One decode step against the uncompressed cache (Fig. 3b baselines)."""
    p = params_from_list(cfg, list(params_flat))
    b = token.shape[0]
    g = cfg.gqa_group

    x = p["embed"][token]
    cos, sin = kref.rope_angles(pos.astype(jnp.float32), cfg.d_head, cfg.rope_theta)  # [B, D/2]

    attn = jax.vmap(  # over B
        jax.vmap(kref.oracle_attention_ref, in_axes=(0, 0, 0, 0, 0, 0, None)),
        in_axes=(0, 0, 0, 0, 0, 0, None),
    )

    k_news, v_news, attn_prevs, attn_selfs = [], [], [], []
    for i in range(cfg.n_layers):
        h = rmsnorm(x, p[f"l{i}.ln1"])
        q, k, v = _qkv(cfg, p, i, h)
        q = kref.rope_ref(q, cos[:, None, :], sin[:, None, :])
        k = kref.rope_ref(k, cos[:, None, :], sin[:, None, :])
        qg = q.reshape(b, cfg.n_kv_heads, g, cfg.d_head)

        out, attn_prev, attn_self = attn(
            qg, k, v, k_full[:, i], v_full[:, i], mask[:, i], oracle_k
        )
        x = x + out.reshape(b, -1) @ p[f"l{i}.wo"]
        x = x + _mlp(p, i, rmsnorm(x, p[f"l{i}.ln2"]))

        k_news.append(k)
        v_news.append(v)
        attn_prevs.append(attn_prev)
        attn_selfs.append(attn_self)

    logits = rmsnorm(x, p["lnf"]) @ p["unembed"]
    stack = lambda xs: jnp.stack(xs, axis=1)
    return logits, stack(k_news), stack(v_news), stack(attn_prevs), stack(attn_selfs)


# ----------------------------------------------------------------------
# Plain training-time forward (no cache, no pallas — fast on CPU XLA)
# ----------------------------------------------------------------------


def train_forward(cfg: ModelConfig, params: dict, tokens, len_mask):
    """Teacher-forced forward for training: logits f32[B, S, V]."""
    flat = params_to_list(cfg, params)
    logits, *_ = prefill(cfg, flat, tokens, len_mask, use_pallas=False)
    return logits
