"""Synthetic task corpus — the training/eval distribution.

The paper evaluates on Line Retrieval, MMLU, GSM8k, HumanEval and
AlpacaEval against real LLMs. Those models/benchmarks are not available in
this offline image (repro band 0), so we train a small transformer from
scratch on a synthetic mixture whose tasks exercise the same failure mode
the paper studies — answers that depend on *details far back in the
context* — and evaluate compression on held-out samples of each family:

* ``lineret``  — the paper's Line Retrieval, token-level: N key→value
  records, then a query key; answer = its value. (Fig. 3b / Fig. 6 panel.)
* ``multihop`` — 2-hop retrieval: records map keys→keys→values; the query
  requires chaining two lookups (GSM8k "reasoning" proxy).
* ``pattern``  — a repeating k-token motif must be continued exactly
  (HumanEval "strict syntactic agreement" proxy).
* ``filler``   — order-2 Markov text used as LM material and as the
  context padding between records (MMLU/perplexity proxy).

Token layout is mirrored **exactly** in ``rust/src/eval/corpus.rs``; the
constants below are cross-checked by a golden test via the artifact
manifest.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# ---------------------------------------------------------------------
# Vocabulary layout (vocab = 512)
# ---------------------------------------------------------------------

PAD = 0
BOS = 1
REC = 2    # record start
SEP = 3    # key / value separator
QUERY = 4  # query section start
ANS = 5    # answer follows
EOS = 6
HOP = 7    # marks a key→key (hop) record

KEY_BASE = 16
KEY_N = 200
VAL_BASE = 216
VAL_N = 100
FILL_BASE = 316
FILL_N = 96
PAT_BASE = 412
PAT_N = 100

VOCAB = 512

KEY_TOKS = 1  # tokens per key (single-token keys: classic induction)
VAL_TOKS = 2  # tokens per value


@dataclass
class Sample:
    """One training/eval sequence."""

    tokens: np.ndarray        # i64[seq]
    loss_mask: np.ndarray     # f32[seq] — 1 where next-token loss applies
    answer_start: int         # index of first answer token (after ANS)
    answer: np.ndarray        # i64[n_answer] — the expected continuation
    family: str


def _key(rng: np.random.Generator) -> np.ndarray:
    return KEY_BASE + rng.integers(0, KEY_N, size=KEY_TOKS)


def _val(rng: np.random.Generator) -> np.ndarray:
    return VAL_BASE + rng.integers(0, VAL_N, size=VAL_TOKS)


def _distinct_keys(rng: np.random.Generator, n: int) -> list[np.ndarray]:
    seen = set()
    out = []
    while len(out) < n:
        k = _key(rng)
        t = tuple(k.tolist())
        if t not in seen:
            seen.add(t)
            out.append(k)
    return out


def gen_filler(rng: np.random.Generator, n: int) -> np.ndarray:
    """Order-2 Markov stream over the filler alphabet (LM-learnable)."""
    # A fixed sparse transition structure derived from small primes keeps
    # the chain deterministic given the rng, and learnable: each (a, b)
    # context allows only 4 successors.
    out = np.empty(n, dtype=np.int64)
    a, b = rng.integers(0, FILL_N), rng.integers(0, FILL_N)
    for i in range(n):
        succ = (a * 7 + b * 13 + rng.integers(0, 4) * 31) % FILL_N
        out[i] = FILL_BASE + succ
        a, b = b, succ
    return out


def gen_lineret(
    rng: np.random.Generator,
    n_lines: int,
    filler_between: int = 0,
    n_queries: int = 1,
) -> Sample:
    """The paper's line-retrieval task at token level.

    Training uses `n_queries > 1` (a multi-turn retrieval transcript: each
    query block re-asks a random key) for 5–8× denser answer gradient per
    sequence; evaluation always uses a single query (`answer_start`/`answer`
    refer to the FIRST query, and the generation prompt ends at its `ANS`).
    """
    # Record format is CANONICAL INDUCTION: the value immediately follows
    # the key ([REC, k, v1, v2]) and the answer is predicted right after the
    # query key ([QUERY, k] -> v1 v2) — the copy pattern small transformers
    # learn reliably. (A SEP/ANS-indirected format needs skip-offset
    # induction and did not emerge within the 1-core training budget.)
    keys = _distinct_keys(rng, n_lines)
    vals = [_val(rng) for _ in range(n_lines)]
    toks: list[np.ndarray] = [np.array([BOS], dtype=np.int64)]
    for k, v in zip(keys, vals):
        toks.append(np.array([REC], dtype=np.int64))
        toks.append(k)
        toks.append(v)
        if filler_between:
            toks.append(gen_filler(rng, filler_between))

    answer_start = None
    answer = None
    answer_spans = []
    for _ in range(max(1, n_queries)):
        qi = int(rng.integers(0, n_lines))
        toks.append(np.array([QUERY], dtype=np.int64))
        toks.append(keys[qi])
        start = sum(len(t) for t in toks)
        if answer_start is None:
            answer_start = start
            answer = vals[qi].copy()
        answer_spans.append(start)
        toks.append(vals[qi])
    toks.append(np.array([EOS], dtype=np.int64))

    tokens = np.concatenate(toks)
    # Record keys/values are random — predicting them is pure noise, so
    # they get zero weight; structural tokens get a small weight; the
    # retrieval answers dominate the gradient.
    loss_mask = np.zeros(len(tokens), dtype=np.float32)
    for i, t in enumerate(tokens):
        if t in (REC, QUERY, EOS):
            loss_mask[i] = 0.1
    for start in answer_spans:
        loss_mask[start : start + VAL_TOKS] = 1.0
    return Sample(tokens, loss_mask, answer_start, answer, "lineret")


def gen_multihop(rng: np.random.Generator, n_lines: int) -> Sample:
    """2-hop retrieval: key --HOP--> key --SEP--> value."""
    n_chain = max(2, n_lines // 2)
    keys_a = _distinct_keys(rng, n_chain)
    keys_b = _distinct_keys(rng, n_chain)
    vals = [_val(rng) for _ in range(n_chain)]
    toks: list[np.ndarray] = [np.array([BOS], dtype=np.int64)]
    # hop records: a -> b, interleaved with value records: b -> v
    order = rng.permutation(2 * n_chain)
    recs = []
    for i in range(n_chain):
        recs.append(("hop", keys_a[i], keys_b[i]))
        recs.append(("val", keys_b[i], vals[i]))
    for idx in order:
        tag, lhs, rhs = recs[idx]
        toks.append(np.array([REC], dtype=np.int64))
        toks.append(lhs)
        if tag == "hop":
            toks.append(np.array([HOP], dtype=np.int64))
        toks.append(rhs)
    qi = int(rng.integers(0, n_chain))
    toks.append(np.array([QUERY], dtype=np.int64))
    toks.append(keys_a[qi])
    answer_start = sum(len(t) for t in toks)
    answer = vals[qi].copy()
    toks.append(answer)
    toks.append(np.array([EOS], dtype=np.int64))

    tokens = np.concatenate(toks)
    loss_mask = np.zeros(len(tokens), dtype=np.float32)
    for i, t in enumerate(tokens):
        if t in (REC, HOP, QUERY, EOS):
            loss_mask[i] = 0.1
    loss_mask[answer_start : answer_start + VAL_TOKS] = 1.0
    return Sample(tokens, loss_mask, answer_start, answer, "multihop")


def gen_pattern(rng: np.random.Generator, motif_len: int, repeats: int) -> Sample:
    """Continue a repeating motif exactly (strict long-range copy)."""
    motif = PAT_BASE + rng.integers(0, PAT_N, size=motif_len)
    full = np.tile(motif, repeats)
    # the model sees all repeats minus a tail of `motif_len` tokens and must
    # reproduce the tail
    cut = len(full) - motif_len
    tokens = np.concatenate([[BOS], full, [EOS]]).astype(np.int64)
    answer_start = 1 + cut
    answer = full[cut:].copy()
    # every repeat after the first is predictable — full copy loss from the
    # second occurrence on, emphasized on the held-out tail
    loss_mask = np.zeros(len(tokens), dtype=np.float32)
    loss_mask[1 + motif_len : 1 + cut] = 0.25
    loss_mask[answer_start : answer_start + motif_len] = 1.0
    return Sample(tokens, loss_mask, answer_start, answer, "pattern")


def gen_lm(rng: np.random.Generator, n: int) -> Sample:
    """Pure filler LM sample (perplexity proxy)."""
    tokens = np.concatenate([[BOS], gen_filler(rng, n)]).astype(np.int64)
    # low per-position weight: a 150-token LM sample must not out-weigh a
    # 2-token retrieval answer in the batch gradient
    loss_mask = np.full(len(tokens), 0.05, dtype=np.float32)
    loss_mask[0] = 0.0
    return Sample(tokens, loss_mask, 1, tokens[1:].copy(), "filler")


def gen_mixture(rng: np.random.Generator, max_len: int) -> Sample:
    """Sample one sequence from the training mixture, length <= max_len."""
    r = rng.random()
    if r < 0.4:
        # leave room for multiple query blocks
        n_lines = int(rng.integers(3, max(4, min(16, (max_len - 40) // 6))))
        filler = int(rng.integers(0, 3))
        n_queries = int(rng.integers(3, 8))
        s = gen_lineret(rng, n_lines, filler_between=filler, n_queries=n_queries)
    elif r < 0.65:
        n_lines = int(rng.integers(4, min(16, (max_len - 8) // 6)))
        s = gen_multihop(rng, n_lines)
    elif r < 0.85:
        motif = int(rng.integers(3, 8))
        reps = int(rng.integers(3, max(4, (max_len - 2) // motif)))
        s = gen_pattern(rng, motif, min(reps, (max_len - 2) // motif))
    else:
        s = gen_lm(rng, int(rng.integers(16, max_len - 1)))
    if len(s.tokens) > max_len:
        # truncate from the front, keeping BOS — rare, only guards bounds
        t = np.concatenate([[BOS], s.tokens[-(max_len - 1):]]).astype(np.int64)
        m = np.concatenate([[0.0], s.loss_mask[-(max_len - 1):]]).astype(np.float32)
        shift = len(s.tokens) - len(t)
        s = Sample(t, m, max(1, s.answer_start - shift), s.answer, s.family)
    return s


def batch_samples(samples: list[Sample], max_len: int):
    """Pad a list of samples to [B, max_len] token/mask arrays."""
    b = len(samples)
    tokens = np.zeros((b, max_len), dtype=np.int64)
    len_mask = np.zeros((b, max_len), dtype=np.float32)
    loss_mask = np.zeros((b, max_len), dtype=np.float32)
    for i, s in enumerate(samples):
        n = min(len(s.tokens), max_len)
        tokens[i, :n] = s.tokens[:n]
        len_mask[i, :n] = 1.0
        loss_mask[i, :n] = s.loss_mask[:n]
    return tokens, len_mask, loss_mask
