//! Context damage from eviction — the paper's Fig. 1/2 scenario at token
//! level.
//!
//! A "protected fact" (key→value record) is planted early in the context,
//! followed by a long stretch of unrelated material. Under aggressive
//! H2O eviction the early record's KV entries are discarded and the model
//! fails the later query — the token-level analogue of the paper's safety
//! breach / context loss. MiKV retains the same budget but keeps the
//! record in low precision, and the query still succeeds.
//!
//! ```sh
//! cargo run --release --example context_damage
//! ```

use mikv::eval::corpus::{self, BOS, QUERY, REC};
use mikv::model::{CacheMode, Engine, Session};
use mikv::quant::Precision;
use mikv::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let engine = Engine::load("artifacts", "cfg-s")?;
    let dims = engine.dims().clone();
    let mut rng = Pcg32::new(2024);

    let n_trials = 12;
    let mut results: Vec<(String, usize)> = Vec::new();
    let modes: Vec<(String, CacheMode)> = vec![
        ("full cache".into(), CacheMode::Full),
        ("H2O evict @15%".into(), CacheMode::h2o(&dims, 0.15)),
        (
            "MiKV @15% (int2)".into(),
            CacheMode::mikv(&dims, 0.15, Precision::Int2),
        ),
    ];

    for (name, mode) in &modes {
        let mut rng_t = Pcg32::new(rng.next_u64());
        let mut hits = 0;
        for _ in 0..n_trials {
            // The protected fact FIRST, then a wall of distractor records
            // and filler, then the query about the protected fact.
            let key: Vec<i64> =
                vec![corpus::KEY_BASE + rng_t.gen_below(corpus::KEY_N as u32) as i64];
            let val: Vec<i64> = vec![
                corpus::VAL_BASE + rng_t.gen_below(corpus::VAL_N as u32) as i64,
                corpus::VAL_BASE + rng_t.gen_below(corpus::VAL_N as u32) as i64,
            ];
            let mut prompt = vec![BOS, REC];
            prompt.extend(&key);
            prompt.extend(&val);
            // distractors: many later records the policy will prefer
            let distract = corpus::gen_lineret(&mut rng_t, 18, 2);
            prompt.extend(&distract.prompt[1..distract.prompt.len() - 2]);
            prompt.push(QUERY);
            prompt.extend(&key);
            if prompt.len() + 4 >= dims.max_seq {
                prompt.truncate(dims.max_seq - 4);
            }

            let mut sess = Session::new(0, &dims, mode.clone())?;
            let out = engine.generate_greedy(&mut sess, &prompt, val.len(), None)?;
            if out == val {
                hits += 1;
            }
        }
        results.push((name.clone(), hits));
    }

    println!("\nProtected early fact retrieved after long distractor context:");
    println!("(the paper's Fig. 1/2 mechanism: eviction silently drops early context)\n");
    for (name, hits) in &results {
        println!(
            "  {name:<20} {hits}/{n_trials} retrieved {}",
            if *hits * 2 >= n_trials { "" } else { "  ← context damage" }
        );
    }
    Ok(())
}
