//! Quickstart: load the engine, create a MiKV session, generate tokens,
//! and inspect the cache state.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use mikv::eval::corpus;
use mikv::model::{CacheMode, Engine, Session};
use mikv::quant::Precision;
use mikv::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    // 1. Load a model's AOT artifacts (compiled once by `make artifacts`).
    let engine = Engine::load("artifacts", "cfg-s")?;
    let dims = engine.dims().clone();
    println!(
        "model: {} params, {} layers × {} kv-heads × d{}, max_seq {}",
        dims.params, dims.n_layers, dims.n_kv_heads, dims.d_head, dims.max_seq
    );

    // 2. Build a line-retrieval prompt (the paper's probe task).
    let mut rng = Pcg32::new(7);
    let sample = corpus::gen_lineret(&mut rng, 15, 0);
    println!(
        "prompt: {} tokens, expected answer {:?}",
        sample.prompt.len(),
        sample.answer
    );

    // 3. Generate with three cache configurations. Alongside exact-answer
    // retrieval we report whether the compressed cache reproduces the
    // FULL-cache generation (fidelity) — the paper's core claim in a
    // model-quality-independent form.
    let mut full_out: Vec<i64> = Vec::new();
    for (name, mode) in [
        ("full cache (100%)", CacheMode::Full),
        (
            "MiKV 20% + INT2 retained",
            CacheMode::mikv(&dims, 0.2, Precision::Int2),
        ),
        ("H2O eviction 20%", CacheMode::h2o(&dims, 0.2)),
    ] {
        let mut sess = Session::new(0, &dims, mode)?;
        let out = engine.generate_greedy(&mut sess, &sample.prompt, sample.answer.len(), None)?;
        let verdict = if out == sample.answer {
            "✓ retrieved"
        } else if full_out.is_empty() || out == full_out {
            "= matches full cache"
        } else {
            "✗ diverged from full cache"
        };
        println!(
            "{name:<28} -> {:?}  {verdict}  (cache {:.1}% of FP16)",
            out,
            sess.cache.cache_size_pct()
        );
        if full_out.is_empty() {
            full_out = out;
        }
    }
    Ok(())
}
