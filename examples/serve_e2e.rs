//! End-to-end serving driver (the repo's headline validation run).
//!
//! Boots the full stack — engine (AOT artifacts trained by `make
//! artifacts`), continuous-batching coordinator, TCP JSON-lines server —
//! then drives a batched workload of line-retrieval requests through real
//! sockets with a mix of cache modes, and reports accuracy, latency
//! percentiles, throughput, and cache compression. Recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_e2e -- --requests 24
//! ```

use mikv::coordinator::{CompressionSpec, Coordinator, CoordinatorConfig, Op};
use mikv::eval::corpus;
use mikv::model::Engine;
use mikv::server::RequestBuilder;
use mikv::util::cli::Args;
use mikv::util::json::Json;
use mikv::util::rng::Pcg32;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let artifacts = args.get_str("artifacts", "artifacts");
    let model = args.get_str("model", "cfg-s");
    let n_requests = args.get("requests", 24usize)?;
    let port: u16 = args.get("port", 7791u16)?;

    // --- boot the server stack ---
    // PJRT handles are not Send, so the engine/coordinator stay on the MAIN
    // thread; the TCP listener and the benchmark client run on workers.
    let engine = Engine::load(&artifacts, &model)?;
    let (tx, rx) = std::sync::mpsc::channel::<Op>();
    let listener = std::net::TcpListener::bind(("127.0.0.1", port))?;
    std::thread::spawn(move || {
        let _ = mikv::server::serve(listener, tx);
    });
    std::thread::spawn(move || {
        if let Err(e) = run_client(port, n_requests) {
            eprintln!("client error: {e}");
            std::process::exit(1);
        }
        std::process::exit(0);
    });
    Coordinator::new(
        engine,
        CoordinatorConfig {
            max_active: 8,
            prefill_chunk: 4,
            ..Default::default()
        },
    )
    .run(rx);
    Ok(())
}

/// Drive the mixed-mode workload through a real socket and print the report.
fn run_client(port: u16, n_requests: usize) -> anyhow::Result<()> {
    // --- client: mixed-mode line-retrieval workload over the socket ---
    let stream = TcpStream::connect(("127.0.0.1", port))?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);

    let mut rng = Pcg32::new(99);
    let specs = [
        CompressionSpec::full(),
        CompressionSpec::mikv(0.25, "int2"),
        CompressionSpec::mikv(0.2, "int2"),
        CompressionSpec::h2o(0.25),
    ];
    let mut expected: Vec<Vec<i64>> = Vec::new();
    let t0 = Instant::now();
    for i in 0..n_requests {
        let sample = corpus::gen_lineret(&mut rng, 14, 1);
        let line = RequestBuilder::generate(i as u64)
            .prompt(&sample.prompt)
            .max_new(sample.answer.len())
            .compression(specs[i % specs.len()].clone())
            .legacy()
            .build();
        writer.write_all(line.as_bytes())?;
        writer.write_all(b"\n")?;
        expected.push(sample.answer);
    }

    // --- collect responses ---
    let mut per_mode: Vec<(usize, usize, f64, f64)> = vec![(0, 0, 0.0, 0.0); specs.len()];
    let mut latencies = Vec::new();
    let mut got = 0usize;
    for line in reader.lines() {
        let v = Json::parse(&line?)?;
        let id = (v.field_i64("id")? & 0xFFFF_FFFF) as usize;
        let tokens: Vec<i64> = v
            .field_arr("tokens")?
            .iter()
            .map(|t| t.as_i64().unwrap_or(-1))
            .collect();
        let m = id % specs.len();
        per_mode[m].1 += 1;
        if tokens == expected[id] {
            per_mode[m].0 += 1;
        }
        per_mode[m].2 += v.field_f64("cache_pct")?;
        per_mode[m].3 += v.field_f64("latency_ms")?;
        latencies.push(v.field_f64("latency_ms")?);
        got += 1;
        if got == n_requests {
            break;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(writer);

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!("\n=== serve_e2e: {n_requests} requests over TCP, wall {wall:.2}s ===");
    println!(
        "throughput: {:.1} req/s | latency p50 {:.0}ms p99 {:.0}ms",
        n_requests as f64 / wall,
        latencies[latencies.len() / 2],
        latencies[latencies.len() - 1],
    );
    let names = ["full", "mikv 25%", "mikv 20%", "h2o 25%"];
    for (name, (hit, n, cache, lat)) in names.iter().zip(&per_mode) {
        if *n == 0 {
            continue;
        }
        println!(
            "  {name:<10} acc {:>5.1}%  cache {:>5.1}%  mean latency {:>6.1}ms  (n={n})",
            100.0 * *hit as f64 / *n as f64,
            cache / *n as f64,
            lat / *n as f64
        );
    }

    Ok(())
}
