//! Streaming Serving-API-v1 client, end-to-end smoke check, and load
//! generator.
//!
//! Modes:
//!
//! * `--stub` — self-hosted smoke (CI runs this): boots the full serving
//!   stack on a deterministic [`StubEngine`] (no artifacts needed) and
//!   drives the v1 API end to end over a real socket — streamed `generate`
//!   with `keep`, a 2-turn `append` continuation proving the cache carries
//!   over, `stats`, `cancel`, and a legacy one-shot regression check. Any
//!   violated invariant exits non-zero.
//! * `--load` — load generator: `--conns M` concurrent connections ×
//!   `--turns K` turns each (`--max-new` tokens per turn). Self-hosts a
//!   sharded stub runtime with `--workers N` engine workers (per-session
//!   decode cost `--delay-us`), or targets a running server via `--addr`.
//!   `--scenario steady|bursty|heavy-tail|flash-crowd|chatty` shapes the
//!   arrival process; `--qos` boots the self-hosted stack with the QoS
//!   admission layer (fair queuing + shedding), and `--priority batch`
//!   tags every turn for the batch lane. Prints tokens/s, TTFT/latency
//!   percentiles, per-connection p99 spread and per-worker utilization.
//! * `--chaos` — fault-injection smoke (CI runs this too): boots the
//!   sharded stub stack with a deterministic `--fault-plan` (default arms
//!   worker panics and writer stalls), drives a load through it, and
//!   checks that every turn reaches a terminal event, panics were
//!   survived (restart counters reconcile with the plan), and nothing
//!   leaks.
//! * default — connects to a running `mikv serve` at `--addr` and runs the
//!   same smoke workflow against the real engine.
//!
//! ```sh
//! cargo run --release --example client -- --stub
//! cargo run --release --example client -- --load --workers 4 --conns 12
//! mikv serve --port 7777 &
//! cargo run --release --example client -- --addr 127.0.0.1:7777
//! ```

use mikv::coordinator::{CompressionSpec, Coordinator, CoordinatorConfig, Op, Priority, QosConfig};
use mikv::model::StubEngine;
use mikv::server::loadgen::{
    run_load, with_stub_stack_full, with_stub_stack_qos, LoadConfig, Scenario,
};
use mikv::server::{Client, RequestBuilder, ServeConfig};
use mikv::util::cli::Args;
use mikv::util::faults::{FaultPlan, FaultSite};
use mikv::util::json::Json;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    if args.flag("chaos") {
        return chaos_mode(&args);
    }
    if args.flag("load") {
        return load_mode(&args);
    }
    if !args.flag("stub") {
        let addr = args.get_str("addr", "127.0.0.1:7777");
        return drive(&addr);
    }

    // Self-hosted: stub engine + coordinator + TCP server, then the same
    // client workflow over a real socket.
    let engine = StubEngine::new(StubEngine::test_dims(256));
    let (tx, rx) = std::sync::mpsc::channel::<Op>();
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    std::thread::spawn(move || {
        let _ = mikv::server::serve(listener, tx);
    });
    let driver = std::thread::spawn(move || drive(&addr));
    Coordinator::new(engine, CoordinatorConfig::default())
        .run_until(rx, || driver.is_finished());
    driver.join().expect("driver panicked")?;
    println!("serving API v1 smoke: OK");
    Ok(())
}

/// Fault-injection smoke (CI runs this): boots the sharded stub stack
/// with a deterministic [`FaultPlan`] arming worker panics and writer
/// stalls, drives a multi-turn load through it, and checks the fault-
/// domain contract — every turn reaches a terminal event (`run_load`
/// returning Ok means no connection hung), worker panics were survived
/// and counted, and the run leaves no cold-tier state behind.
fn chaos_mode(args: &Args) -> anyhow::Result<()> {
    let spec = args.get_str(
        "fault-plan",
        "seed=7;engine_step_panic:every=30,limit=2;conn_stall:every=25,ms=5",
    );
    let plan = FaultPlan::parse(&spec)?;
    let workers = args.get_nonzero("workers", 2)?;
    let mut base = StubEngine::new(StubEngine::test_dims(256));
    base.faults = plan.clone();
    let coord_cfg = CoordinatorConfig {
        faults: plan.clone(),
        ..CoordinatorConfig::default()
    };
    let serve_cfg = ServeConfig {
        faults: plan.clone(),
        ..ServeConfig::default()
    };
    let cfg = LoadConfig {
        conns: args.get_nonzero("conns", 6)?,
        turns: args.get_nonzero("turns", 3)?,
        ..LoadConfig::default()
    };
    let total = cfg.conns * cfg.turns;
    let load_cfg = cfg.clone();
    let report = with_stub_stack_full(workers, coord_cfg, None, base, serve_cfg, move |addr| {
        run_load(&addr, &load_cfg)
    })??;
    println!(
        "chaos: {} turns -> {} ok, {} err | {} worker restart(s), \
         {} session(s) lost, {} recovered, {} event(s) shed",
        total,
        report.turns_ok,
        report.turns_err,
        report.worker_restarts,
        report.sessions_lost,
        report.sessions_recovered,
        report.events_dropped,
    );
    anyhow::ensure!(
        report.turns_ok + report.turns_err == total,
        "every turn must reach a terminal event ({} + {} != {total})",
        report.turns_ok,
        report.turns_err,
    );
    anyhow::ensure!(
        report.worker_restarts == plan.fired(FaultSite::EngineStepPanic),
        "restarts ({}) must reconcile with injected panics ({})",
        report.worker_restarts,
        plan.fired(FaultSite::EngineStepPanic),
    );
    anyhow::ensure!(report.turns_ok > 0, "chaos run completed no turns at all");
    anyhow::ensure!(
        report.parked_cold_sessions == 0 && report.cold_bytes == 0,
        "chaos run leaked cold-tier state"
    );
    println!("fault-injection smoke: OK");
    Ok(())
}

/// Load-generator mode: M concurrent connections × K turns against a
/// sharded stub runtime (or `--addr` for an external server).
fn load_mode(args: &Args) -> anyhow::Result<()> {
    let scenario_name = args.get_str("scenario", "steady");
    let scenario = Scenario::parse(&scenario_name)
        .ok_or_else(|| anyhow::anyhow!("unknown --scenario '{scenario_name}'"))?;
    let priority_name = args.get_str("priority", "interactive");
    let priority = Priority::parse(&priority_name)
        .ok_or_else(|| anyhow::anyhow!("unknown --priority '{priority_name}'"))?;
    let mut cfg = LoadConfig {
        conns: args.get_nonzero("conns", 8)?,
        turns: args.get_nonzero("turns", 2)?,
        max_new: args.get_nonzero("max-new", 16)?,
        prompt_len: args.get_nonzero("prompt-len", 6)?,
        seed: args.get("seed", 0x10ADu64)?,
        scenario,
        priority,
        max_retries: args.get("retries", 0usize)?,
        ..LoadConfig::default()
    };
    if args.flag("promotion") {
        cfg.spec = cfg.spec.promoted();
    }
    let qos = args.flag("qos").then(QosConfig::default);
    let qos_on = qos.is_some();
    let report = if let Ok(addr) = args.require_str("addr") {
        run_load(&addr, &cfg)?
    } else {
        // Self-hosted sharded runtime on the stub engine.
        let workers = args.get_nonzero("workers", 2)?;
        let mut base = StubEngine::new(StubEngine::test_dims(256));
        base.decode_delay = Duration::from_micros(args.get("delay-us", 300u64)?);
        let load_cfg = cfg.clone();
        with_stub_stack_qos(
            workers,
            CoordinatorConfig::default(),
            qos,
            base,
            move |addr| run_load(&addr, &load_cfg),
        )??
    };
    println!(
        "load: {} conns x {} turns, {} tokens in {:.1}ms -> {:.0} tok/s \
         ({} ok, {} err)",
        cfg.conns,
        cfg.turns,
        report.tokens,
        report.wall.as_secs_f64() * 1e3,
        report.tokens_per_sec,
        report.turns_ok,
        report.turns_err,
    );
    println!(
        "ttft p50 {:.2}ms p99 {:.2}ms | latency p50 {:.2}ms p99 {:.2}ms \
         | assembly p50 {:.1}us p99 {:.1}us",
        report.ttft_p50.as_secs_f64() * 1e3,
        report.ttft_p99.as_secs_f64() * 1e3,
        report.latency_p50.as_secs_f64() * 1e3,
        report.latency_p99.as_secs_f64() * 1e3,
        report.assembly_us_p50,
        report.assembly_us_p99,
    );
    for w in &report.per_worker {
        println!(
            "worker {}: {} turns, {} tokens ({:.0}% of load)",
            w.worker,
            w.completed,
            w.generated_tokens,
            w.share * 100.0
        );
    }
    if report.promotions > 0 || report.thrash_suppressed > 0 {
        println!(
            "promotions: {} ({} thrash-suppressed)",
            report.promotions, report.thrash_suppressed
        );
    }
    println!(
        "fairness: per-conn p99 spread {:.2}x | shed {} batch / {} interactive, \
         {} rate-limited ({} rejections carried retry_after_ms)",
        report.conn_p99_spread,
        report.shed_batch,
        report.shed_interactive,
        report.rate_limited,
        report.rejects_with_hint,
    );
    if report.retries > 0 {
        println!(
            "retries: {} shed-aware re-submissions, {} turn(s) recovered",
            report.retries, report.retry_success
        );
    }
    // A QoS stack is allowed to shed under pressure — those rejections are
    // part of what the run measures. A stock FCFS run must stay clean.
    anyhow::ensure!(
        qos_on || report.turns_err == 0,
        "{} turns failed",
        report.turns_err
    );
    Ok(())
}

/// Exercise every v1 op and the legacy shape; error on any broken invariant.
fn drive(addr: &str) -> anyhow::Result<()> {
    let mut c = Client::connect(addr)?;
    let spec = CompressionSpec::mikv(0.25, "int4");

    // --- Turn 1: streamed generate, keeping the session ---
    let id1 = c.next_id();
    c.submit(
        &RequestBuilder::generate(id1)
            .prompt(&[1, 2, 3, 4, 5])
            .max_new(6)
            .keep(true)
            .compression(spec.clone()),
    )?;
    let (streamed, done) = c.read_turn(id1)?;
    anyhow::ensure!(done.field_str("event")? == "done", "turn 1 failed: {done}");
    let final_tokens: Vec<i64> = done
        .field_arr("tokens")?
        .iter()
        .filter_map(Json::as_i64)
        .collect();
    anyhow::ensure!(
        streamed == final_tokens,
        "streamed {streamed:?} != done tokens {final_tokens:?}"
    );
    anyhow::ensure!(!streamed.is_empty(), "no tokens streamed");
    let session = done.field_i64("session")?;
    let occ1 = done.field_i64("hi_slots")? + done.field_i64("lo_slots")?;
    let bytes1 = done.field_i64("host_bytes")?;
    anyhow::ensure!(occ1 > 0 && bytes1 > 0, "turn 1 reported no footprint");
    println!(
        "turn 1: {} tokens streamed, session {session}, {occ1} slots, {bytes1} B"
    );

    // --- Turn 2: append into the same session ---
    let id2 = c.next_id();
    c.submit(
        &RequestBuilder::append(id2, session as u64)
            .prompt(&[6, 7])
            .max_new(4),
    )?;
    let (streamed2, done2) = c.read_turn(id2)?;
    anyhow::ensure!(done2.field_str("event")? == "done", "turn 2 failed: {done2}");
    anyhow::ensure!(
        done2.field_i64("session")? == session,
        "append must keep the session id"
    );
    let occ2 = done2.field_i64("hi_slots")? + done2.field_i64("lo_slots")?;
    anyhow::ensure!(
        occ2 > occ1,
        "occupancy must carry over and grow ({occ1} -> {occ2})"
    );
    anyhow::ensure!(!streamed2.is_empty(), "turn 2 streamed nothing");
    println!(
        "turn 2: {} tokens streamed, occupancy {occ1} -> {occ2} (cache reused)",
        streamed2.len()
    );

    // --- Stats over the wire ---
    let id3 = c.next_id();
    c.submit(&RequestBuilder::stats(id3))?;
    let (_, stats) = c.read_turn(id3)?;
    anyhow::ensure!(stats.field_str("event")? == "stats", "bad stats: {stats}");
    anyhow::ensure!(stats.field_i64("completed")? >= 2);
    anyhow::ensure!(stats.field_i64("parked_sessions")? >= 1, "session parked");
    println!(
        "stats: {} completed, {} parked session(s), {} pool blocks free",
        stats.field_i64("completed")?,
        stats.field_i64("parked_sessions")?,
        stats.field_i64("pool_free_blocks")?
    );

    // --- Cancel an in-flight long turn ---
    let id4 = c.next_id();
    c.submit(
        &RequestBuilder::append(id4, session as u64)
            .prompt(&[8])
            .max_new(100_000),
    )?;
    let id5 = c.next_id();
    c.submit(&RequestBuilder::cancel(id5, id4))?;
    // The cancel answer and the turn's terminal event can arrive in either
    // order (the turn may even finish naturally first); collect both.
    let mut done4: Option<Json> = None;
    let mut cres: Option<Json> = None;
    while done4.is_none() || cres.is_none() {
        let v = c.recv()?;
        let vid = v.field("id").ok().and_then(Json::as_i64);
        let ev = v.field_str("event").unwrap_or("").to_string();
        match (vid, ev.as_str()) {
            (Some(i), "done") | (Some(i), "error") if i == id4 as i64 => done4 = Some(v),
            (Some(i), "cancelled") if i == id5 as i64 => cres = Some(v),
            (Some(i), "token") if i == id4 as i64 => {}
            _ => anyhow::bail!("unexpected line: {v}"),
        }
    }
    let done4 = done4.expect("loop exits with both set");
    let cancelled = done4.field("cancelled").ok() == Some(&Json::Bool(true));
    println!(
        "cancel: turn ended via {} ({} tokens)",
        if cancelled { "cancel" } else { "natural completion" },
        done4.field_arr("tokens").map(|t| t.len()).unwrap_or(0)
    );
    let cres = cres.expect("loop exits with both set");
    anyhow::ensure!(cres.field_str("event")? == "cancelled", "bad: {cres}");

    // --- Legacy one-shot shape still answered in one line, no events ---
    let id6 = c.request(&[1, 2, 3], 2, &CompressionSpec::full())?;
    let legacy = c.recv()?;
    anyhow::ensure!(
        legacy.field("event").is_err(),
        "legacy reply must not be an event: {legacy}"
    );
    anyhow::ensure!(legacy.field_i64("id")? == id6 as i64);
    anyhow::ensure!(legacy.field("error")? == &Json::Null, "legacy error");
    anyhow::ensure!(legacy.field_arr("tokens")?.len() == 2);
    println!("legacy one-shot: OK");
    Ok(())
}
