//! A deliberately small Rust "lexer": strips comments and string/char
//! literals (replacing them with spaces so line/column structure survives),
//! tracks `#[cfg(test)]` / `mod tests` regions by brace matching, and parses
//! the repo's lint waiver comments.
//!
//! This is not a general Rust parser — it only needs to be sound for the
//! subset of Rust this repository writes (rustfmt-formatted, no exotic
//! macros defining items with unbalanced braces). The build image is
//! offline, so pulling `syn` is not an option; a few hundred lines of state
//! machine is the right size for four rules.

/// How a waiver applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WaiverScope {
    /// Covers its own line and the next line.
    Site,
    /// Covers the next `fn` item's entire brace-matched body.
    Function,
    /// Commentary only — validated for rule-name typos, waives nothing.
    Note,
}

/// A parsed `// lint: <rule>-ok[...]: <reason>` annotation.
#[derive(Debug, Clone)]
pub struct Waiver {
    pub rule: String,
    pub scope: WaiverScope,
    pub reason: String,
    /// 0-based line the comment sits on.
    pub line: usize,
    /// 0-based inclusive line range the waiver covers.
    pub start: usize,
    pub end: usize,
}

/// A malformed directive (reported as a finding by the rule engine).
#[derive(Debug, Clone)]
pub struct Problem {
    pub line: usize,
    pub message: String,
}

/// One scanned source file.
pub struct SourceFile {
    /// Repo-relative path with forward slashes.
    pub path: String,
    /// Raw, unstripped text (used by the wire-error rule).
    pub raw: String,
    /// Comment/string-stripped code, split into lines.
    pub lines: Vec<String>,
    /// Per line: inside a `#[cfg(test)]` / `mod tests` region.
    pub test: Vec<bool>,
    pub waivers: Vec<Waiver>,
    pub problems: Vec<Problem>,
}

/// Rule names the waiver grammar accepts.
pub const RULE_NAMES: [&str; 4] = [
    "panic-free-serving",
    "hot-path-alloc-free",
    "relaxed-ordering-audit",
    "wire-error-exhaustiveness",
];

pub fn is_ident(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Blank comments and string/char literals to spaces (newlines kept), and
/// collect line comments as `(line, text)` for waiver parsing.
fn strip(src: &str) -> (String, Vec<(usize, String)>) {
    let b = src.as_bytes();
    let mut out: Vec<u8> = Vec::with_capacity(b.len());
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut line = 0usize;
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            out.push(b'\n');
            line += 1;
            i += 1;
            continue;
        }
        // Line comment (also `///` and `//!`): blank to end of line.
        if c == b'/' && b.get(i + 1) == Some(&b'/') {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                out.push(b' ');
                i += 1;
            }
            comments.push((line, String::from_utf8_lossy(&b[start..i]).into_owned()));
            continue;
        }
        // Block comment, nesting tracked.
        if c == b'/' && b.get(i + 1) == Some(&b'*') {
            let mut depth = 0usize;
            while i < b.len() {
                if b[i] == b'\n' {
                    out.push(b'\n');
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                    depth += 1;
                    out.push(b' ');
                    out.push(b' ');
                    i += 2;
                } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                    depth -= 1;
                    out.push(b' ');
                    out.push(b' ');
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    out.push(b' ');
                    i += 1;
                }
            }
            continue;
        }
        let ident_prev = i > 0 && is_ident(b[i - 1]);
        // Raw string `r"…"` / `r#"…"#` (optionally `br`-prefixed).
        if !ident_prev && (c == b'r' || (c == b'b' && b.get(i + 1) == Some(&b'r'))) {
            let mut j = i + if c == b'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while b.get(j) == Some(&b'#') {
                hashes += 1;
                j += 1;
            }
            if b.get(j) == Some(&b'"') {
                for _ in i..=j {
                    out.push(b' ');
                }
                i = j + 1;
                while i < b.len() {
                    if b[i] == b'\n' {
                        out.push(b'\n');
                        line += 1;
                        i += 1;
                        continue;
                    }
                    if b[i] == b'"' {
                        let mut k = i + 1;
                        let mut h = 0usize;
                        while h < hashes && b.get(k) == Some(&b'#') {
                            h += 1;
                            k += 1;
                        }
                        if h == hashes {
                            for _ in i..k {
                                out.push(b' ');
                            }
                            i = k;
                            break;
                        }
                    }
                    out.push(b' ');
                    i += 1;
                }
                continue;
            }
            // Not a raw string: fall through and copy the `r`/`b` byte.
        }
        // Plain string (also `b"…"`).
        if c == b'"' || (!ident_prev && c == b'b' && b.get(i + 1) == Some(&b'"')) {
            if c == b'b' {
                out.push(b' ');
                i += 1;
            }
            out.push(b' ');
            i += 1;
            while i < b.len() {
                match b[i] {
                    b'\n' => {
                        out.push(b'\n');
                        line += 1;
                        i += 1;
                    }
                    b'\\' => {
                        out.push(b' ');
                        i += 1;
                        if i < b.len() {
                            if b[i] == b'\n' {
                                out.push(b'\n');
                                line += 1;
                            } else {
                                out.push(b' ');
                            }
                            i += 1;
                        }
                    }
                    b'"' => {
                        out.push(b' ');
                        i += 1;
                        break;
                    }
                    _ => {
                        out.push(b' ');
                        i += 1;
                    }
                }
            }
            continue;
        }
        // Char literal vs lifetime.
        if c == b'\'' {
            if b.get(i + 1) == Some(&b'\\') {
                // Escaped char: consume through the closing quote (covers
                // `'\n'`, `'\''`, `'\u{1F600}'`, `'\x41'`).
                out.push(b' ');
                out.push(b' ');
                i += 2;
                if i < b.len() {
                    out.push(b' ');
                    i += 1;
                }
                while i < b.len() && b[i] != b'\'' && b[i] != b'\n' {
                    out.push(b' ');
                    i += 1;
                }
                if i < b.len() && b[i] == b'\'' {
                    out.push(b' ');
                    i += 1;
                }
                continue;
            }
            if let Some(&n1) = b.get(i + 1) {
                let w = utf8_len(n1);
                if n1 != b'\'' && b.get(i + 1 + w) == Some(&b'\'') {
                    for _ in 0..w + 2 {
                        out.push(b' ');
                    }
                    i += w + 2;
                    continue;
                }
            }
            // Lifetime (`'a`, `'static`): keep the quote, scan on.
            out.push(c);
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    debug_assert_eq!(out.len(), b.len(), "strip must preserve byte offsets");
    (String::from_utf8_lossy(&out).into_owned(), comments)
}

/// Does `line` contain the token `mod tests` (word-bounded)?
fn has_mod_tests(line: &str) -> bool {
    let b = line.as_bytes();
    let needle = b"mod tests";
    let mut i = 0usize;
    while i + needle.len() <= b.len() {
        if &b[i..i + needle.len()] == needle {
            let before_ok = i == 0 || !is_ident(b[i - 1]);
            let after_ok = match b.get(i + needle.len()) {
                Some(&c) => !is_ident(c),
                None => true,
            };
            if before_ok && after_ok {
                return true;
            }
        }
        i += 1;
    }
    false
}

/// Mark every line inside a `#[cfg(test)]` item or a `mod tests` body.
fn mark_tests(lines: &[String]) -> Vec<bool> {
    let mut test = vec![false; lines.len()];
    let mut pending = false;
    let mut in_test = false;
    let mut depth: i64 = 0;
    let mut exit_depth: i64 = 0;
    for (ln, line) in lines.iter().enumerate() {
        if in_test {
            test[ln] = true;
        } else if line.contains("#[cfg(test)]") || has_mod_tests(line) {
            pending = true;
            test[ln] = true;
        }
        for c in line.bytes() {
            match c {
                b'{' => {
                    if pending && !in_test {
                        in_test = true;
                        exit_depth = depth;
                        pending = false;
                        test[ln] = true;
                    }
                    depth += 1;
                }
                b'}' => {
                    depth -= 1;
                    if in_test && depth == exit_depth {
                        in_test = false;
                        test[ln] = true;
                    }
                }
                _ => {}
            }
        }
    }
    test
}

/// Does `line` contain the keyword `fn` (word-bounded)?
fn has_fn_token(line: &str) -> bool {
    let b = line.as_bytes();
    let mut i = 0usize;
    while i + 2 <= b.len() {
        if &b[i..i + 2] == b"fn" {
            let before_ok = i == 0 || !is_ident(b[i - 1]);
            let after_ok = match b.get(i + 2) {
                Some(&c) => !is_ident(c),
                None => true,
            };
            if before_ok && after_ok {
                return true;
            }
        }
        i += 1;
    }
    false
}

/// Last line of the `fn` item starting at (or just after) `wline`, found by
/// brace matching on stripped lines. `None` if no nearby `fn` follows.
fn fn_region_end(lines: &[String], wline: usize) -> Option<usize> {
    let mut fn_line = None;
    for (ln, line) in lines.iter().enumerate().skip(wline) {
        // The waiver must sit adjacent to its fn (doc comments between are
        // stripped to blank lines and still count toward the window).
        if ln > wline + 8 {
            break;
        }
        if has_fn_token(line) {
            fn_line = Some(ln);
            break;
        }
    }
    let start = fn_line?;
    let mut depth: i64 = 0;
    let mut opened = false;
    for (ln, line) in lines.iter().enumerate().skip(start) {
        for c in line.bytes() {
            match c {
                b'{' => {
                    depth += 1;
                    opened = true;
                }
                b'}' => {
                    depth -= 1;
                    if opened && depth == 0 {
                        return Some(ln);
                    }
                }
                _ => {}
            }
        }
    }
    None
}

/// Parse one comment for a lint directive. Comments that do not start with
/// `lint:` (after `//`/`///`/`//!` and whitespace) are ignored.
fn parse_directive(
    line: usize,
    text: &str,
    lines: &[String],
    waivers: &mut Vec<Waiver>,
    problems: &mut Vec<Problem>,
) {
    let body = text.trim_start_matches('/').trim_start_matches('!').trim();
    let Some(rest) = body.strip_prefix("lint:") else {
        return;
    };
    let rest = rest.trim();
    let Some(colon) = rest.find(':') else {
        problems.push(Problem {
            line,
            message: "malformed lint directive: missing ':' before reason".to_string(),
        });
        return;
    };
    let head = rest[..colon].trim();
    let reason = rest[colon + 1..].trim();
    let (rule, scope) = if let Some(inner) = head.strip_prefix("note(") {
        match inner.strip_suffix(')') {
            Some(rule) => (rule, WaiverScope::Note),
            None => {
                problems.push(Problem {
                    line,
                    message: format!("malformed lint note: '{head}'"),
                });
                return;
            }
        }
    } else if let Some(rule) = head.strip_suffix("-ok(fn)") {
        (rule, WaiverScope::Function)
    } else if let Some(rule) = head.strip_suffix("-ok") {
        (rule, WaiverScope::Site)
    } else {
        problems.push(Problem {
            line,
            message: format!("malformed lint directive head: '{head}'"),
        });
        return;
    };
    if !RULE_NAMES.contains(&rule) {
        problems.push(Problem {
            line,
            message: format!("unknown lint rule '{rule}' in waiver"),
        });
        return;
    }
    if reason.is_empty() {
        problems.push(Problem {
            line,
            message: format!("waiver for '{rule}' is missing a reason"),
        });
        return;
    }
    let (start, end) = match scope {
        WaiverScope::Site => (line, line + 1),
        WaiverScope::Function => match fn_region_end(lines, line) {
            Some(end) => (line, end),
            None => {
                problems.push(Problem {
                    line,
                    message: format!("fn-scope waiver for '{rule}' is not followed by a fn item"),
                });
                return;
            }
        },
        // Notes waive nothing; give them an empty region.
        WaiverScope::Note => (usize::MAX, 0),
    };
    waivers.push(Waiver {
        rule: rule.to_string(),
        scope,
        reason: reason.to_string(),
        line,
        start,
        end,
    });
}

/// Scan one file into its stripped/annotated form.
pub fn scan(path: &str, raw: &str) -> SourceFile {
    let (stripped, comments) = strip(raw);
    let lines: Vec<String> = stripped.lines().map(|l| l.to_string()).collect();
    let test = mark_tests(&lines);
    let mut waivers = Vec::new();
    let mut problems = Vec::new();
    for (line, text) in &comments {
        parse_directive(*line, text, &lines, &mut waivers, &mut problems);
    }
    SourceFile {
        path: path.to_string(),
        raw: raw.to_string(),
        lines,
        test,
        waivers,
        problems,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_and_strings() {
        let src = "let a = \"x.unwrap()\"; // c.unwrap()\nlet b = 1; /* vec![0] */ let c = 2;\n";
        let (s, comments) = strip(src);
        assert!(!s.contains("unwrap"), "stripped: {s}");
        assert!(!s.contains("vec!"));
        assert!(s.contains("let a ="));
        assert!(s.contains("let c = 2;"));
        assert_eq!(comments.len(), 1);
        assert_eq!(comments[0].0, 0);
        assert!(comments[0].1.contains("c.unwrap()"));
    }

    #[test]
    fn strips_raw_strings_and_char_literals() {
        let src = "let r = r#\"a[0].unwrap()\"#; let c = '['; let l: &'static str = \"\";";
        let (s, _) = strip(src);
        assert!(!s.contains("unwrap"));
        assert!(!s.contains('['), "char literal must be blanked: {s}");
        assert!(s.contains("'static"), "lifetimes survive: {s}");
    }

    #[test]
    fn strips_escaped_quote_char() {
        let src = "let q = '\\''; let x = a[i];";
        let (s, _) = strip(src);
        assert!(s.contains("a[i]"), "code after the literal survives: {s}");
    }

    #[test]
    fn marks_cfg_test_and_mod_tests_regions() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn live2() {}\n";
        let sf = scan("x.rs", src);
        assert!(!sf.test[0]);
        assert!(sf.test[1] && sf.test[2] && sf.test[3] && sf.test[4]);
        assert!(!sf.test[5]);
    }

    #[test]
    fn parses_site_and_fn_waivers() {
        let src = "\
// lint: panic-free-serving-ok: index bounded by construction\n\
let x = a[0];\n\
// lint: hot-path-alloc-free-ok(fn): constructor, not per-step\n\
fn build() {\n    let v = vec![0];\n    v\n}\n";
        let sf = scan("x.rs", src);
        assert_eq!(sf.problems.len(), 0, "{:?}", sf.problems);
        assert_eq!(sf.waivers.len(), 2);
        assert_eq!(sf.waivers[0].scope, WaiverScope::Site);
        assert_eq!(sf.waivers[0].start, 0);
        assert_eq!(sf.waivers[0].end, 1);
        assert_eq!(sf.waivers[1].scope, WaiverScope::Function);
        assert_eq!(sf.waivers[1].start, 2);
        assert_eq!(sf.waivers[1].end, 6);
    }

    #[test]
    fn rejects_bad_directives() {
        let cases = [
            ("// lint: panic-free-serving-ok:", "missing a reason"),
            ("// lint: no-such-rule-ok: why", "unknown lint rule"),
            ("// lint: panic-free-serving-ok", "missing ':'"),
        ];
        for (src, expect) in cases {
            let sf = scan("x.rs", src);
            assert_eq!(sf.waivers.len(), 0, "{src}");
            assert_eq!(sf.problems.len(), 1, "{src}");
            assert!(sf.problems[0].message.contains(expect), "{src}: {}", sf.problems[0].message);
        }
    }

    #[test]
    fn notes_validate_but_do_not_waive() {
        let src = "// lint: note(relaxed-ordering-audit): pairs with the Acquire load\nlet x = 1;";
        let sf = scan("x.rs", src);
        assert_eq!(sf.problems.len(), 0);
        assert_eq!(sf.waivers.len(), 1);
        assert_eq!(sf.waivers[0].scope, WaiverScope::Note);
        assert!(sf.waivers[0].start > sf.waivers[0].end, "note covers nothing");
    }
}
