//! `mikv-lint` — repo-specific static analysis for the mikv serving stack.
//!
//! Enforces the invariants the serving runtime is built on (see
//! ARCHITECTURE.md § "Invariants & lint catalog"): panic-free serving code,
//! allocation-free decode hot paths, audited relaxed atomics, and an
//! exhaustive wire-error table. Violations are suppressed per site with
//! `// lint: <rule>-ok: <reason>` waivers; every waiver must carry a
//! reason, and the waivers themselves are what make the audit readable.
//!
//! ```text
//! cargo run -p mikv-lint                  # report
//! cargo run -p mikv-lint -- --deny        # exit 1 on any violation (CI)
//! cargo run -p mikv-lint -- --json out.json
//! ```

mod lexer;
mod rules;

use rules::Finding;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Parsed command line.
struct Options {
    root: PathBuf,
    deny: bool,
    json: Option<PathBuf>,
    verbose: bool,
}

fn usage() -> &'static str {
    "usage: mikv-lint [--root <dir>] [--deny] [--json <path>] [--verbose]"
}

fn parse_args(args: &[String]) -> Result<Options, String> {
    let mut opts = Options {
        root: PathBuf::from("."),
        deny: false,
        json: None,
        verbose: false,
    };
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(v) => opts.root = PathBuf::from(v),
                None => return Err("--root needs a value".to_string()),
            },
            "--json" => match it.next() {
                Some(v) => opts.json = Some(PathBuf::from(v)),
                None => return Err("--json needs a value".to_string()),
            },
            "--deny" => opts.deny = true,
            "--verbose" => opts.verbose = true,
            other => return Err(format!("unknown argument '{other}'")),
        }
    }
    Ok(opts)
}

/// Every `.rs` file under `dir`, sorted for stable output.
fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, p: &Path) -> String {
    let rel = p.strip_prefix(root).unwrap_or(p);
    rel.to_string_lossy().replace('\\', "/")
}

/// Run all rules over the tree rooted at `root`. Only I/O errors are `Err`;
/// rule hits come back as findings.
fn analyze_tree(root: &Path) -> io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    walk(&root.join("rust/src"), &mut files)?;
    let mut findings = Vec::new();
    let mut request_raw = String::new();
    let mut proto_raw = String::new();
    for p in &files {
        let raw = fs::read_to_string(p)?;
        let rel = rel_path(root, p);
        if rel == "rust/src/coordinator/request.rs" {
            request_raw = raw.clone();
        }
        if rel == "rust/src/server/proto.rs" {
            proto_raw = raw.clone();
        }
        let sf = lexer::scan(&rel, &raw);
        findings.extend(rules::check_file(&sf));
    }
    let arch_raw = fs::read_to_string(root.join("ARCHITECTURE.md")).unwrap_or_default();
    findings.extend(rules::check_wire_errors(&request_raw, &proto_raw, &arch_raw));
    Ok(findings)
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn to_json(findings: &[Finding]) -> String {
    let mut out = String::from("{\"findings\":[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let (waived, reason) = match &f.waived {
            Some(r) => ("true", json_escape(r)),
            None => ("false", String::new()),
        };
        out.push_str(&format!(
            "{{\"rule\":\"{}\",\"path\":\"{}\",\"line\":{},\"message\":\"{}\",\
             \"waived\":{},\"reason\":\"{}\"}}",
            f.rule,
            json_escape(&f.path),
            f.line,
            json_escape(&f.message),
            waived,
            reason
        ));
    }
    let violations = findings.iter().filter(|f| f.waived.is_none()).count();
    let waived = findings.len() - violations;
    out.push_str(&format!(
        "],\"violations\":{violations},\"waived\":{waived}}}"
    ));
    out
}

fn run(args: &[String]) -> i32 {
    let opts = match parse_args(args) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("mikv-lint: {e}\n{}", usage());
            return 2;
        }
    };
    let findings = match analyze_tree(&opts.root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("mikv-lint: cannot scan {}: {e}", opts.root.display());
            return 2;
        }
    };
    if let Some(path) = &opts.json {
        if let Err(e) = fs::write(path, to_json(&findings)) {
            eprintln!("mikv-lint: cannot write {}: {e}", path.display());
            return 2;
        }
    }
    let mut violations = 0usize;
    let mut waived = 0usize;
    for f in &findings {
        match &f.waived {
            Some(reason) => {
                waived += 1;
                if opts.verbose {
                    println!(
                        "{}:{}: [{}] waived: {} — {}",
                        f.path, f.line, f.rule, f.message, reason
                    );
                }
            }
            None => {
                violations += 1;
                println!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.message);
            }
        }
    }
    println!("mikv-lint: {violations} violation(s), {waived} waived site(s)");
    if opts.deny && violations > 0 {
        return 1;
    }
    0
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(run(&args));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_root() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("../..")
    }

    /// The acceptance gate itself: the real tree has zero unwaived
    /// violations, and every waived site carries a non-empty reason.
    #[test]
    fn real_tree_passes_deny() {
        let findings = analyze_tree(&repo_root()).expect("scan repo");
        let violations: Vec<_> = findings.iter().filter(|f| f.waived.is_none()).collect();
        assert!(
            violations.is_empty(),
            "unwaived violations:\n{}",
            violations
                .iter()
                .map(|f| format!("  {}:{}: [{}] {}", f.path, f.line, f.rule, f.message))
                .collect::<Vec<_>>()
                .join("\n")
        );
        for f in &findings {
            if let Some(reason) = &f.waived {
                assert!(!reason.is_empty(), "empty waiver reason at {}:{}", f.path, f.line);
            }
        }
        // the audit is real: the tree does carry documented waivers
        assert!(findings.iter().any(|f| f.waived.is_some()));
    }

    /// Seeding an `unwrap()` into non-test proto.rs code flips the tree to
    /// failing — the ISSUE's acceptance demonstration.
    #[test]
    fn seeded_unwrap_in_real_proto_fails() {
        let root = repo_root();
        let raw = fs::read_to_string(root.join("rust/src/server/proto.rs")).expect("read proto");
        let seeded = format!("{raw}\nfn seeded() -> u32 {{\n    None::<u32>.unwrap()\n}}\n");
        let sf = lexer::scan("rust/src/server/proto.rs", &seeded);
        let violations = rules::check_file(&sf)
            .into_iter()
            .filter(|f| f.waived.is_none())
            .count();
        assert!(violations > 0, "seeded unwrap must be caught");
    }

    /// Same demonstration for a `vec![]` in the assembly hot path.
    #[test]
    fn seeded_vec_in_real_assembly_fails() {
        let root = repo_root();
        let raw = fs::read_to_string(root.join("rust/src/model/assembly.rs")).expect("read asm");
        let seeded = format!("{raw}\nfn seeded() -> Vec<f32> {{\n    vec![0.0; 8]\n}}\n");
        let sf = lexer::scan("rust/src/model/assembly.rs", &seeded);
        let violations = rules::check_file(&sf)
            .into_iter()
            .filter(|f| f.waived.is_none())
            .count();
        assert!(violations > 0, "seeded vec! must be caught");
    }

    #[test]
    fn deny_exit_codes() {
        // a clean tree in deny mode exits 0 through run()
        let root = repo_root().to_string_lossy().into_owned();
        let code = run(&["--root".to_string(), root, "--deny".to_string()]);
        assert_eq!(code, 0, "deny mode must pass on the real tree");
        // bad arguments exit 2
        assert_eq!(run(&["--bogus".to_string()]), 2);
    }

    #[test]
    fn json_output_shape() {
        let f = Finding {
            rule: rules::PANIC_FREE,
            path: "a\"b.rs".to_string(),
            line: 3,
            message: "x".to_string(),
            waived: None,
        };
        let j = to_json(&[f]);
        assert!(j.contains("\"violations\":1"));
        assert!(j.contains("a\\\"b.rs"));
    }
}
