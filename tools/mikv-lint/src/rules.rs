//! The four mikv invariant rules, applied to [`lexer::SourceFile`]s.
//!
//! * `panic-free-serving` — no `unwrap`/`expect`/panic-family macros/slice
//!   indexing in non-test serving code.
//! * `hot-path-alloc-free` — no allocating constructs in the decode
//!   hot-path modules.
//! * `relaxed-ordering-audit` — every `Ordering::Relaxed` carries a waiver
//!   naming why relaxed suffices.
//! * `wire-error-exhaustiveness` — every `ErrorCode` wire string appears in
//!   the proto module docs and the ARCHITECTURE.md error table.

use crate::lexer::{is_ident, SourceFile, WaiverScope};

pub const PANIC_FREE: &str = "panic-free-serving";
pub const ALLOC_FREE: &str = "hot-path-alloc-free";
pub const RELAXED: &str = "relaxed-ordering-audit";
pub const WIRE_ERRORS: &str = "wire-error-exhaustiveness";
/// Pseudo-rule for malformed waiver annotations themselves.
pub const WAIVER_GRAMMAR: &str = "waiver-grammar";

/// One rule hit. `waived` carries the waiver reason when a matching
/// annotation covers the site; unwaived findings are violations.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    /// 1-based line for display.
    pub line: usize,
    pub message: String,
    pub waived: Option<String>,
}

/// Files subject to `panic-free-serving`. `util/faults.rs` is here
/// because fault-injection probes sit inline on serving hot paths — the
/// seam that *injects* failures must never itself be a panic source.
pub fn panic_free_scope(path: &str) -> bool {
    path.starts_with("rust/src/server/")
        || path.starts_with("rust/src/coordinator/")
        || path == "rust/src/model/session.rs"
        || path == "rust/src/model/assembly.rs"
        || path == "rust/src/kvcache/spill.rs"
        || path == "rust/src/util/faults.rs"
}

/// Files subject to `hot-path-alloc-free`. `coordinator/qos.rs` is here
/// because the DRR pop/push and token-bucket admit run on the scheduler's
/// admission loop for every turn — steady-state queue churn must recycle
/// its ring/queue storage, not allocate per op. `kvcache/merge.rs` is here
/// because the fold/nearest-neighbor helpers run inside the per-token
/// demotion pass of `append_token` — merge must fold in place, never
/// allocate per evicted slot.
pub fn alloc_free_scope(path: &str) -> bool {
    matches!(
        path,
        "rust/src/model/assembly.rs"
            | "rust/src/kvcache/dirty.rs"
            | "rust/src/kvcache/tier.rs"
            | "rust/src/kvcache/merge.rs"
            | "rust/src/kvcache/spill.rs"
            | "rust/src/quant/packing.rs"
            | "rust/src/coordinator/qos.rs"
    )
}

/// `.name(` with an exact method-name match, so `unwrap_or`/`to_vec2` style
/// near-misses never trigger.
fn has_method_call(code: &str, name: &str) -> bool {
    let b = code.as_bytes();
    let mut i = 0usize;
    while i < b.len() {
        if b[i] == b'.' {
            let start = i + 1;
            let mut j = start;
            while j < b.len() && is_ident(b[j]) {
                j += 1;
            }
            if &code[start..j] == name {
                let mut k = j;
                while k < b.len() && b[k] == b' ' {
                    k += 1;
                }
                if b.get(k) == Some(&b'(') {
                    return true;
                }
            }
            i = j.max(i + 1);
        } else {
            i += 1;
        }
    }
    false
}

/// `name!` with a word boundary before the name.
fn has_macro(code: &str, name: &str) -> bool {
    let b = code.as_bytes();
    let n = name.len();
    let mut i = 0usize;
    while i + n < b.len() {
        if &code[i..i + n] == name && b[i + n] == b'!' && (i == 0 || !is_ident(b[i - 1])) {
            return true;
        }
        i += 1;
    }
    false
}

/// A path token like `Vec::new`, word-bounded on both sides.
fn has_path_token(code: &str, token: &str) -> bool {
    let b = code.as_bytes();
    let n = token.len();
    let mut i = 0usize;
    while i + n <= b.len() {
        if &code[i..i + n] == token {
            let before_ok = i == 0 || !is_ident(b[i - 1]);
            let after_ok = match b.get(i + n) {
                Some(&c) => !is_ident(c),
                None => true,
            };
            if before_ok && after_ok {
                return true;
            }
        }
        i += 1;
    }
    false
}

/// `[` immediately preceded by an identifier char, `)` or `]` is an index
/// expression (array types, `vec![`, attributes and slice patterns all have
/// a different preceding byte).
fn has_slice_index(code: &str) -> bool {
    let b = code.as_bytes();
    for i in 1..b.len() {
        if b[i] == b'[' {
            let p = b[i - 1];
            if is_ident(p) || p == b')' || p == b']' {
                return true;
            }
        }
    }
    false
}

const PANIC_MACROS: [&str; 4] = ["panic!", "unreachable!", "todo!", "unimplemented!"];

fn panic_tokens(code: &str) -> Vec<&'static str> {
    let mut hits = Vec::new();
    if has_method_call(code, "unwrap") {
        hits.push(".unwrap()");
    }
    if has_method_call(code, "expect") {
        hits.push(".expect()");
    }
    for name in PANIC_MACROS {
        if has_macro(code, &name[..name.len() - 1]) {
            hits.push(name);
        }
    }
    if has_slice_index(code) {
        hits.push("slice indexing");
    }
    hits
}

fn alloc_tokens(code: &str) -> Vec<&'static str> {
    let mut hits = Vec::new();
    if has_macro(code, "vec") {
        hits.push("vec!");
    }
    if has_path_token(code, "Vec::new") {
        hits.push("Vec::new");
    }
    if has_method_call(code, "to_vec") {
        hits.push(".to_vec()");
    }
    if code.contains("collect::<Vec") {
        hits.push("collect::<Vec<..>>");
    }
    if has_macro(code, "format") {
        hits.push("format!");
    }
    hits
}

/// Apply waivers: the reason of the first covering waiver, if any.
fn waived(sf: &SourceFile, rule: &str, line: usize) -> Option<String> {
    sf.waivers
        .iter()
        .find(|w| {
            w.scope != WaiverScope::Note && w.rule == rule && w.start <= line && line <= w.end
        })
        .map(|w| w.reason.clone())
}

/// Run the per-file rules over one scanned file.
pub fn check_file(sf: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for p in &sf.problems {
        out.push(Finding {
            rule: WAIVER_GRAMMAR,
            path: sf.path.clone(),
            line: p.line + 1,
            message: p.message.clone(),
            waived: None,
        });
    }
    let in_panic_scope = panic_free_scope(&sf.path);
    let in_alloc_scope = alloc_free_scope(&sf.path);
    for (ln, code) in sf.lines.iter().enumerate() {
        if sf.test[ln] {
            continue;
        }
        if in_panic_scope {
            let hits = panic_tokens(code);
            if !hits.is_empty() {
                out.push(Finding {
                    rule: PANIC_FREE,
                    path: sf.path.clone(),
                    line: ln + 1,
                    message: format!("panicking construct in serving code: {}", hits.join(", ")),
                    waived: waived(sf, PANIC_FREE, ln),
                });
            }
        }
        if in_alloc_scope {
            let hits = alloc_tokens(code);
            if !hits.is_empty() {
                out.push(Finding {
                    rule: ALLOC_FREE,
                    path: sf.path.clone(),
                    line: ln + 1,
                    message: format!("allocation in decode hot path: {}", hits.join(", ")),
                    waived: waived(sf, ALLOC_FREE, ln),
                });
            }
        }
        if code.contains("Ordering::Relaxed") {
            out.push(Finding {
                rule: RELAXED,
                path: sf.path.clone(),
                line: ln + 1,
                message: "Ordering::Relaxed requires a waiver naming why relaxed is safe"
                    .to_string(),
                waived: waived(sf, RELAXED, ln),
            });
        }
    }
    out
}

/// Extract the wire strings from `ErrorCode::as_str` (`=> "code"` arms).
pub fn wire_codes(request_raw: &str) -> Vec<String> {
    let Some(start) = request_raw.find("fn as_str") else {
        return Vec::new();
    };
    let region = &request_raw[start..];
    let Some(open) = region.find('{') else {
        return Vec::new();
    };
    let b = region.as_bytes();
    let mut depth: i64 = 0;
    let mut end = region.len();
    for (i, &c) in b.iter().enumerate().skip(open) {
        match c {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    end = i;
                    break;
                }
            }
            _ => {}
        }
    }
    let mut codes = Vec::new();
    let mut rest = &region[open..end];
    while let Some(p) = rest.find("=> \"") {
        let tail = &rest[p + 4..];
        match tail.find('"') {
            Some(q) => {
                codes.push(tail[..q].to_string());
                rest = &tail[q..];
            }
            None => break,
        }
    }
    codes
}

/// `wire-error-exhaustiveness`: every code from `ErrorCode::as_str` must
/// appear (backticked) in proto.rs and in the ARCHITECTURE.md error table.
pub fn check_wire_errors(request_raw: &str, proto_raw: &str, arch_raw: &str) -> Vec<Finding> {
    let codes = wire_codes(request_raw);
    let mut out = Vec::new();
    if codes.is_empty() {
        out.push(Finding {
            rule: WIRE_ERRORS,
            path: "rust/src/coordinator/request.rs".to_string(),
            line: 1,
            message: "could not extract any wire codes from ErrorCode::as_str".to_string(),
            waived: None,
        });
        return out;
    }
    for code in &codes {
        let tick = format!("`{code}`");
        if !proto_raw.contains(&tick) {
            out.push(Finding {
                rule: WIRE_ERRORS,
                path: "rust/src/server/proto.rs".to_string(),
                line: 1,
                message: format!("wire code {tick} is not documented in the proto module"),
                waived: None,
            });
        }
        if !arch_raw.contains(&tick) {
            out.push(Finding {
                rule: WIRE_ERRORS,
                path: "ARCHITECTURE.md".to_string(),
                line: 1,
                message: format!("wire code {tick} missing from the ARCHITECTURE.md error table"),
                waived: None,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::scan;

    fn violations(path: &str, src: &str) -> Vec<Finding> {
        check_file(&scan(path, src))
            .into_iter()
            .filter(|f| f.waived.is_none())
            .collect()
    }

    #[test]
    fn seeded_unwrap_in_proto_is_a_violation() {
        let src = "fn decode() -> u32 {\n    let x: Option<u32> = None;\n    x.unwrap()\n}\n";
        let v = violations("rust/src/server/proto.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, PANIC_FREE);
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn unwrap_in_test_region_is_ignored() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { None::<u32>.unwrap(); }\n}\n";
        assert!(violations("rust/src/server/proto.rs", src).is_empty());
    }

    #[test]
    fn unwrap_or_family_is_not_flagged() {
        let src = "fn f(o: Option<u32>) -> u32 {\n    o.unwrap_or(0) + o.unwrap_or_default()\n}\n";
        assert!(violations("rust/src/server/proto.rs", src).is_empty());
    }

    #[test]
    fn slice_index_heuristics() {
        let bad = "fn f(a: &[f32], i: usize) -> f32 {\n    a[i]\n}\n";
        assert_eq!(violations("rust/src/server/proto.rs", bad).len(), 1);
        let ok = "fn f(a: &mut [f32; 4]) {\n    #[allow(dead_code)]\n    let v = vec![0u8];\n}\n";
        assert!(violations("rust/src/server/proto.rs", ok).is_empty());
    }

    #[test]
    fn seeded_vec_macro_in_assembly_is_a_violation() {
        let src = "fn f() -> Vec<f32> {\n    vec![0.0; 8]\n}\n";
        let v = violations("rust/src/model/assembly.rs", src);
        // assembly.rs is in both scopes; only the alloc rule fires here.
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, ALLOC_FREE);
    }

    #[test]
    fn merge_module_is_in_alloc_scope() {
        // The merge fold runs inside the per-token demotion pass: it must
        // fold into the neighbor's existing storage, never allocate per
        // evicted slot. It is *not* in the panic-free scope (the manager
        // validates slot indices before calling in).
        let src = "fn f() -> Vec<f32> {\n    vec![0.0; 8]\n}\n";
        let v = violations("rust/src/kvcache/merge.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, ALLOC_FREE);
        let panicky = "fn g(a: &[f32]) -> f32 {\n    a[0]\n}\n";
        let v = violations("rust/src/kvcache/merge.rs", panicky);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn qos_module_is_in_both_scopes() {
        // The QoS admission structures run on the scheduler's per-op
        // admission loop: allocation there is a violation, same as the
        // decode hot path.
        let src = "fn f() -> Vec<u32> {\n    vec![1]\n}\n";
        let v = violations("rust/src/coordinator/qos.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, ALLOC_FREE);
        let panicky = "fn g(a: &[u32]) -> u32 {\n    a[0]\n}\n";
        let v = violations("rust/src/coordinator/qos.rs", panicky);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, PANIC_FREE);
    }

    #[test]
    fn faults_module_is_in_panic_free_scope() {
        // The fault-injection seam probes inline on serving hot paths; a
        // panic in the seam itself would be a fault the plan never armed.
        let panicky = "fn g(a: &[u32]) -> u32 {\n    a[0].unwrap()\n}\n";
        let v = violations("rust/src/util/faults.rs", panicky);
        assert!(
            v.iter().all(|f| f.rule == PANIC_FREE) && v.len() >= 2,
            "{v:?}"
        );
        // ...but the rest of util/ stays out of scope.
        assert!(violations("rust/src/util/json.rs", panicky).is_empty());
    }

    #[test]
    fn alloc_tokens_cover_issue_list() {
        let src = concat!(
            "fn f(x: &[u8]) {\n",
            "    let a = Vec::new();\n",
            "    let b = x.to_vec();\n",
            "    let c: Vec<u8> = x.iter().copied().collect::<Vec<u8>>();\n",
            "    let d = format!(\"{}\", 1);\n",
            "}\n",
        );
        let v = violations("rust/src/quant/packing.rs", src);
        assert_eq!(v.len(), 4, "{v:?}");
    }

    #[test]
    fn site_waiver_suppresses_with_reason() {
        let src = concat!(
            "fn f(a: &[f32]) -> f32 {\n",
            "    // lint: panic-free-serving-ok: i bounded by caller contract\n",
            "    a[0]\n}\n",
        );
        let sf = scan("rust/src/server/proto.rs", src);
        let all = check_file(&sf);
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].waived.as_deref(), Some("i bounded by caller contract"));
    }

    #[test]
    fn fn_waiver_covers_whole_body() {
        let src = concat!(
            "// lint: panic-free-serving-ok(fn): all offsets asserted at entry\n",
            "fn f(a: &[f32]) -> f32 {\n    let x = a[0];\n    let y = a[1];\n    x + y\n}\n",
        );
        let all = check_file(&scan("rust/src/server/proto.rs", src));
        assert_eq!(all.len(), 2);
        assert!(all.iter().all(|f| f.waived.is_some()));
    }

    #[test]
    fn relaxed_ordering_requires_waiver_everywhere() {
        let src = "fn f(c: &AtomicU64) -> u64 {\n    c.load(Ordering::Relaxed)\n}\n";
        let v = violations("rust/src/util/anything.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, RELAXED);
        let waived_src = concat!(
            "fn f(c: &AtomicU64) -> u64 {\n",
            "    // lint: relaxed-ordering-audit-ok: monotonic counter, no ordering needed\n",
            "    c.load(Ordering::Relaxed)\n}\n",
        );
        assert!(violations("rust/src/util/anything.rs", waived_src).is_empty());
    }

    #[test]
    fn waiver_without_reason_is_a_violation() {
        let src = "fn f(a: &[f32]) -> f32 {\n    // lint: panic-free-serving-ok:\n    a[0]\n}\n";
        let v = violations("rust/src/server/proto.rs", src);
        assert!(v.iter().any(|f| f.rule == WAIVER_GRAMMAR), "{v:?}");
        // the unwaived index is still reported too
        assert!(v.iter().any(|f| f.rule == PANIC_FREE), "{v:?}");
    }

    #[test]
    fn wire_codes_extraction_and_cross_check() {
        let req = concat!(
            "impl ErrorCode {\n",
            "    pub fn as_str(self) -> &'static str {\n",
            "        match self {\n",
            "            ErrorCode::BadRequest => \"bad_request\",\n",
            "            ErrorCode::Internal => \"internal\",\n",
            "        }\n    }\n}\n",
        );
        assert_eq!(wire_codes(req), vec!["bad_request", "internal"]);
        let proto = "//! codes: `bad_request`, `internal`";
        let arch = "| `bad_request` | ... |";
        let v = check_wire_errors(req, proto, arch);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].path, "ARCHITECTURE.md");
        assert!(v[0].message.contains("`internal`"));
    }
}
